# repolint: zone=train
"""Bad: a function that accepts ``now=`` but reads the wall clock anyway —
callers injecting a logical time silently get mixed clock domains."""
import time


def expire(entries, now=0.0):
    cutoff = time.monotonic() - 60.0
    return [e for e in entries if e > cutoff]

# repolint: zone=serve
"""Bad: wall-clock read inside an injected-clock zone (the PR-5 bug)."""
import time


def latency(start):
    return time.monotonic() - start

# repolint: zone=serve
"""Bad: a hardcoded impl= literal outside the kernel layer pins one
backend instead of threading it from config."""


def plan(engine, points):
    return engine.run(points, impl="pallas")

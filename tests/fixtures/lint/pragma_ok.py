# repolint: zone=train
"""A justified pragma: the timestamp is read by another process, so wall
clock is the correct domain — the suppression is used, hence clean."""
import time


def stamp():
    return time.time()  # repolint: disable=CLK003

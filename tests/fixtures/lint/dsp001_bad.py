# repolint: zone=kernels.ops
"""Bad: impl pinned to a backend at the signature and never resolved —
bifurcates the executable cache and ignores $REPRO_POINT_IMPL."""
from repro.kernels import vjp


def pinned_blocks(points, *, impl="pallas"):
    return vjp.index_producer(lambda pts: pts)(points)

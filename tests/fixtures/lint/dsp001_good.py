# repolint: zone=kernels.ops
"""Good: impl defaults to None and resolves through resolve_impl()."""
from repro.kernels import vjp
from repro.kernels.ops import resolve_impl


def routed_blocks(points, *, impl: str | None = None):
    impl = resolve_impl(impl)
    return vjp.index_producer(lambda pts: pts)(points)

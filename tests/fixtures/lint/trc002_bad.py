# repolint: zone=kernels
"""Bad: Python branch on a traced value inside a jitted function — works in
interpret mode, raises TracerBoolConversionError under jit."""
import jax


@jax.jit
def clamp(x, limit):
    if x > limit:
        return limit
    return x

# repolint: zone=train
"""Good: the injected ``now`` is the only time source in the function."""


def expire(entries, now=0.0):
    cutoff = now - 60.0
    return [e for e in entries if e > cutoff]

# repolint: zone=kernels
"""Good: every cached parameter is annotated hashable-by-construction."""
import functools


@functools.lru_cache(maxsize=None)
def _op(k: int, impl: str, chunk: int | None):
    return (k, impl, chunk)

# repolint: zone=train
"""Good: intervals come from the monotonic clock."""
import time


def step_time(fn):
    t0 = time.monotonic()
    fn()
    return time.monotonic() - t0

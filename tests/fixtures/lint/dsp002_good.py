# repolint: zone=serve
"""Good: the backend threads from config through the call site."""


def plan(engine, points, cfg):
    return engine.run(points, impl=cfg.impl)

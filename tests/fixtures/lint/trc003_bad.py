# repolint: zone=kernels
"""Bad: host numpy materialized inside a Pallas kernel body."""
import numpy as np
from jax.experimental import pallas as pl


def _double_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] + np.zeros((8, 128), np.float32)


def double(x):
    return pl.pallas_call(_double_kernel, out_shape=x)(x)

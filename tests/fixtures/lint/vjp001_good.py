# repolint: zone=kernels.ops
"""Good: the wrapper routes through a cached factory whose body classifies
the op via kernels/vjp.py, and resolves impl eagerly."""
import functools

from repro.kernels import vjp
from repro.kernels.ops import resolve_impl


@functools.lru_cache(maxsize=None)
def _good_op(k: int, impl: str):
    return vjp.index_producer(lambda pts: pts[:, :k])


def good_blocks(points, *, k: int = 8, impl: str | None = None):
    impl = resolve_impl(impl)
    return _good_op(k, impl)(points)

# repolint: zone=kernels
"""Bad: lru_cache over an unannotated parameter — a traced/array argument
would poison the cache (crash, or pin device memory + stale results)."""
import functools


@functools.lru_cache(maxsize=None)
def _op(k, impl: str):
    return (k, impl)

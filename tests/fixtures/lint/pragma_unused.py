# repolint: zone=train
"""A stale pragma: nothing on the line violates CLK003 anymore, so the
suppression itself is flagged (PRG001) and cannot linger."""
import time


def stamp():
    return time.monotonic()  # repolint: disable=CLK003

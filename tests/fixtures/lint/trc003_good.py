# repolint: zone=kernels
"""Good: kernel body uses only jnp ops on refs and Python scalars."""
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _double_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * jnp.float32(2.0)


def double(x):
    return pl.pallas_call(_double_kernel, out_shape=x)(x)

# repolint: zone=serve
"""Good: time enters only through the injected clock (a ``clock=`` default
is a reference, not a call, and is exactly the sanctioned pattern)."""
import time


class Engine:
    def __init__(self, clock=time.monotonic):
        self._clock = clock

    def latency(self, start):
        return self._clock() - start

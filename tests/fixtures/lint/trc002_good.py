# repolint: zone=kernels
"""Good: branches only on statics — static_argnames params and shapes."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("mode",))
def clamp(x, mode):
    if mode == "relu":
        return jnp.maximum(x, 0.0)
    if x.shape[0] > 8:
        return x * 0.5
    return x

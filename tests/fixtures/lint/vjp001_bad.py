# repolint: zone=kernels.ops
"""Bad: a public op wrapper with no kernels/vjp.py classification — it
would ship forward-only (the gap PR 5 closed)."""
from repro.kernels.ops import resolve_impl


def broken_blocks(points, *, impl=None, chunk=None):
    impl = resolve_impl(impl)
    return points

# repolint: zone=train
"""Bad: time.time() for an interval — not monotonic, NTP steps skew it."""
import time


def step_time(fn):
    t0 = time.time()
    fn()
    return time.time() - t0

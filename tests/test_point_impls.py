"""Backend-dispatch parity: impl="pallas" (interpret) vs the kernels/ref.py
oracle through the one public dispatch layer (kernels/ops.py), at
non-lane-multiple block sizes, with empty blocks and all-invalid masks —
plus end-to-end pnn.apply equivalence between the two backends."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import synthetic
from repro.kernels import ops
from repro.models import pnn

jax.config.update("jax_platform_name", "cpu")

# Deliberately off the 128-lane / 8-sublane boundaries.
ODD_SHAPES = [(3, 65), (2, 200), (5, 33)]


def blocks(seed, nb, bs, empty_blocks=0, all_invalid=False):
    """Random blocks; the first ``empty_blocks`` blocks have zero valid
    points, and ``all_invalid`` masks out every point everywhere."""
    rng = np.random.default_rng(seed)
    coords = rng.normal(0, 1, (nb, bs, 3)).astype(np.float32)
    nvalid = rng.integers(1, bs + 1, nb)
    nvalid[:empty_blocks] = 0
    if all_invalid:
        nvalid[:] = 0
    mask = np.arange(bs)[None, :] < nvalid[:, None]
    return jnp.asarray(coords), jnp.asarray(mask)


def both(fn):
    return fn("pallas"), fn("xla")


@pytest.mark.parametrize("nb,bs", ODD_SHAPES)
@pytest.mark.parametrize("empty,invalid", [(0, False), (1, False),
                                           (0, True)])
def test_fps_parity(nb, bs, empty, invalid):
    coords, mask = blocks(0, nb, bs, empty, invalid)
    a, b = both(lambda i: ops.fps_blocks(coords, mask, k=7, impl=i))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("nb,w", ODD_SHAPES)
@pytest.mark.parametrize("empty,invalid", [(0, False), (1, False),
                                           (0, True)])
def test_ball_query_parity(nb, w, empty, invalid):
    win, wmask = blocks(1, nb, w, empty, invalid)
    centers, cmask = blocks(2, nb, 13, empty, invalid)   # kc=13: odd too
    a, b = both(lambda i: ops.ball_query_blocks(
        centers, cmask, win, wmask, radius=0.8, num=5, impl=i))
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-5)
    assert a[0].shape == (nb, 13, 5)      # sliced back, not lane-padded


@pytest.mark.parametrize("nb,w", ODD_SHAPES)
@pytest.mark.parametrize("empty,invalid", [(0, False), (1, False),
                                           (0, True)])
def test_knn_parity(nb, w, empty, invalid):
    win, wmask = blocks(3, nb, w, empty, invalid)
    queries, _ = blocks(4, nb, 11)
    a, b = both(lambda i: ops.knn_blocks(queries, win, wmask, k=3, impl=i))
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-5)
    assert a[0].shape == (nb, 11, 3)


@pytest.mark.parametrize("nb,w", ODD_SHAPES)
def test_gather_parity(nb, w):
    rng = np.random.default_rng(5)
    feats = jnp.asarray(rng.normal(0, 1, (nb, w, 9)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, w, (nb, 17)), jnp.int32)
    a, b = both(lambda i: ops.gather_blocks(feats, idx, impl=i))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    assert a.shape == (nb, 17, 9)


@pytest.mark.parametrize("nb,bs", ODD_SHAPES)
@pytest.mark.parametrize("empty,invalid", [(0, False), (1, False),
                                           (0, True)])
def test_fractal_level_parity(nb, bs, empty, invalid):
    coords, mask = blocks(6, nb, bs, empty, invalid)
    mid = jnp.asarray(np.random.default_rng(7).normal(0, 0.5, (nb,)),
                      jnp.float32)
    a, b = both(lambda i: ops.fractal_level_blocks(coords, mask, mid,
                                                   da=0, db=1, impl=i))
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-6)


@pytest.mark.parametrize("impl", ["pallas", "xla"])
def test_chunked_dispatch_matches_unchunked(impl):
    win, wmask = blocks(8, 7, 65, empty_blocks=1)
    centers, cmask = blocks(9, 7, 9)
    a = ops.ball_query_blocks(centers, cmask, win, wmask, radius=0.8,
                              num=4, impl=impl, chunk=3)
    b = ops.ball_query_blocks(centers, cmask, win, wmask, radius=0.8,
                              num=4, impl=impl)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("impl", ["pallas", "xla"])
def test_fps_exhaustion_repeats_last_valid(impl):
    """Contract: k beyond a block's valid count repeats the *last valid*
    selection (kernels/ref.py) instead of emitting garbage indices; empty
    blocks degenerate to repeating index 0.  Both impls."""
    coords, mask = blocks(11, 3, 40, empty_blocks=1)
    mask = mask.at[1].set(jnp.arange(40) < 3)   # block 1: 3 valid points
    idx = np.asarray(ops.fps_blocks(coords, mask, k=7, impl=impl))
    assert (idx[0] == 0).all()                  # empty block
    assert len(set(idx[1][:3])) == 3            # 3 distinct valid picks
    assert set(idx[1][:3]) <= {0, 1, 2}
    assert (idx[1][3:] == idx[1][2]).all()      # then repeat-last-valid
    b = np.asarray(ops.fps_blocks(coords, mask, k=7,
                                  impl="xla" if impl == "pallas"
                                  else "pallas"))
    np.testing.assert_array_equal(idx, b)       # impls agree exactly


@pytest.mark.parametrize("impl", ["pallas", "xla"])
def test_gather_out_of_range_fetches_zeros(impl):
    """Contract: idx outside [0, W) fetches zeros — the one-hot kernel's
    natural behavior, which the ref oracle (and hence the VJP's dropped
    backward rows) must match."""
    rng = np.random.default_rng(12)
    w = 33
    feats = jnp.asarray(rng.normal(1, 1, (2, w, 5)).astype(np.float32))
    idx = jnp.asarray([[-1, 0, w - 1, w, w + 90],
                       [3, -7, 1, 2, w]], jnp.int32)
    out = np.asarray(ops.gather_blocks(feats, idx, impl=impl))
    ok = (np.asarray(idx) >= 0) & (np.asarray(idx) < w)
    assert (out[~ok] == 0).all()
    np.testing.assert_allclose(out[0, 1], np.asarray(feats[0, 0]),
                               rtol=1e-6)
    np.testing.assert_allclose(out[0, 2], np.asarray(feats[0, w - 1]),
                               rtol=1e-6)


def test_resolve_impl(monkeypatch):
    monkeypatch.delenv("REPRO_POINT_IMPL", raising=False)
    assert ops.resolve_impl("xla") == "xla"
    assert ops.resolve_impl(None, default="pallas") == "pallas"
    monkeypatch.setenv("REPRO_POINT_IMPL", "xla")
    assert ops.resolve_impl(None, default="pallas") == "xla"
    assert ops.resolve_impl("pallas") == "pallas"  # explicit arg wins
    with pytest.raises(ValueError, match="impl"):
        ops.resolve_impl("cuda")


@pytest.mark.parametrize("task,n,th", [("cls", 256, 32), ("seg", 384, 64)])
def test_pnn_apply_pallas_matches_xla(task, n, th):
    """End-to-end: the full BPPO pipeline produces the same logits through
    the Pallas kernels (interpret) as through the jnp oracle."""
    cfg = pnn.PNNConfig(variant="pointnet2", task=task, n_points=n,
                        point_ops="bppo", th=th)
    import dataclasses
    params = pnn.init(jax.random.PRNGKey(0), cfg)
    batch = (synthetic.classification_batch if task == "cls"
             else synthetic.segmentation_batch)
    pts, _ = batch(0, 0, 1, n)
    a = pnn.apply(params, dataclasses.replace(cfg, impl="pallas"), pts[0])
    b = pnn.apply(params, dataclasses.replace(cfg, impl="xla"), pts[0])
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-4)

"""repro.serve: bucket admission, deadline batching, plan-cache warmth.

Covers the DESIGN.md §9 contract: minimal-fitting bucket selection, padded
results equal to the unpadded oracle on real points, plan-cache hit on the
second request of a bucket, exactly one compile per (bucket, impl) across
a mixed-size stream (trace counter), deadline flush of a partially filled
microbatch, and mesh dispatch equal to the single-device path.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import serve
from repro.data import synthetic
from repro.kernels import ops as kops
from repro.models import pnn

jax.config.update("jax_platform_name", "cpu")


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


# ---------------------------------------------------------------------------
# Admission: bucket selection + padding.
# ---------------------------------------------------------------------------

def test_bucket_select_minimal_fitting():
    policy = serve.BucketPolicy((16384, 4096, 65536))   # normalized sorted
    assert policy.buckets == (4096, 16384, 65536)
    assert policy.select(1) == 4096
    assert policy.select(4096) == 4096                  # exact fit
    assert policy.select(4097) == 16384                 # minimal, not max
    assert policy.select(65536) == 65536
    with pytest.raises(ValueError, match="exceeds"):
        policy.select(65537)
    with pytest.raises(ValueError, match="non-empty"):
        policy.select(0)
    with pytest.raises(ValueError, match="positive"):
        serve.BucketPolicy(())


def test_pad_points_contract():
    coords = jnp.arange(15.0).reshape(5, 3)
    padded, valid = kops.pad_points(coords, 8)
    assert padded.shape == (8, 3) and valid.shape == (8,)
    np.testing.assert_array_equal(np.asarray(padded[:5]), np.asarray(coords))
    assert np.asarray(valid).tolist() == [True] * 5 + [False] * 3
    # existing invalid slots survive; no-op when already at size
    c2, v2 = kops.pad_points(coords, 5, valid=jnp.array([1, 1, 0, 1, 1],
                                                        bool))
    assert c2.shape == (5, 3) and not bool(v2[2])
    with pytest.raises(ValueError, match="pad"):
        kops.pad_points(coords, 4)


def test_scene_bucket_admission_minimal_fitting():
    """Scene-scale ladder admission: pad() lands each cloud in its minimal
    bucket with exactly the real points valid (satellite for §10: tile
    clouds of 3–16k points flow through these buckets)."""
    policy = serve.BucketPolicy((4096, 16384, 65536))
    for n, want in [(3000, 4096), (4096, 4096), (4097, 16384),
                    (12000, 16384), (16384, 16384), (16385, 65536)]:
        b, c, v = policy.pad(jnp.zeros((n, 3), jnp.float32))
        assert b == want and c.shape == (want, 3) and v.shape == (want,)
        assert int(v.sum()) == n and bool(v[:n].all())


@pytest.mark.parametrize("bucket,n", [(4096, 3000), (16384, 12000)])
def test_padded_matches_unpadded_oracle_scene_buckets(bucket, n):
    """§9 padding invisibility at the scene-scale buckets (previously only
    exercised at 256): the forward over a cloud padded to 4096/16384
    equals the unpadded forward on the real points.

    Window placement keys on valid counts (window_view), so the large
    invalid tail cannot move search windows; the single-SA-stage model
    bounds CPU cost; the seed satisfies the no-sample-truncation budget
    of §9 (asserted below so data drift fails loudly)."""
    cfg = pnn.scene_seg(n=n, th=256, impl="xla", widths=(16, 16),
                        fp=(16, 16))
    params = pnn.init(jax.random.PRNGKey(0), cfg)
    pts = jnp.asarray(synthetic.scene(0, n)[0])

    from repro import core
    part = jax.jit(lambda p: core.partition(p, th=256))(pts)
    k_out = int(round(cfg.stages[0].rate * n))
    samp = core.blockwise_fps(part, rate=cfg.stages[0].rate, k_out=k_out,
                              bs=256, impl="xla")
    assert int(samp.total) <= k_out, "seed no longer satisfies §9 budget"

    oracle = np.asarray(jax.jit(
        lambda c: pnn.apply(params, cfg, c))(pts))
    padded, valid = kops.pad_points(pts, bucket)
    cfg_b = dataclasses.replace(cfg, n_points=bucket)
    out = np.asarray(jax.jit(
        lambda c, v: pnn.apply(params, cfg_b, c, valid=v))(padded, valid))
    np.testing.assert_allclose(out[:n], oracle, rtol=1e-5, atol=1e-5)


def test_padded_matches_unpadded_oracle():
    """Bucket padding is invisible: the padded forward equals the unpadded
    oracle on the real points (seg covers FPS + grouping + interpolation).

    Sizes are chosen so no sample/window truncation occurs (w = 2*th covers
    every parent; quota sum fits k_out) — see DESIGN.md §9 for why padding
    is only exact under those conditions."""
    n, bucket, th = 200, 256, 64
    cfg = pnn.PNNConfig(variant="pointnet2", task="seg", n_points=n,
                        point_ops="bppo", th=th, impl="xla")
    params = pnn.init(jax.random.PRNGKey(0), cfg)
    pts, _ = synthetic.segmentation_batch(0, 0, 1, n)
    oracle = np.asarray(pnn.apply(params, cfg, pts[0]))

    padded, valid = kops.pad_points(pts[0], bucket)
    cfg_b = dataclasses.replace(cfg, n_points=bucket)
    out = np.asarray(pnn.apply(params, cfg_b, padded, valid=valid))
    np.testing.assert_allclose(out[:n], oracle, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Queue: FIFO packing + deadline semantics (pure, no compiles).
# ---------------------------------------------------------------------------

def test_queue_full_batch_and_deadline():
    q = serve.MicroBatchQueue(serve.BucketPolicy((64, 128)), microbatch=3,
                              max_wait_s=0.5)
    r1 = q.submit(jnp.zeros((50, 3)), now=0.0)
    r2 = q.submit(jnp.zeros((60, 3)), now=0.1)
    assert r1.bucket == r2.bucket == 64 and q.pending() == 2
    assert q.ready(now=0.4) == []                  # under deadline, partial
    (mb,) = q.ready(now=0.6)                       # oldest waited >= 0.5
    assert mb.deadline_flush and [r.rid for r in mb.requests] == [r1.rid,
                                                                  r2.rid]
    assert q.pending() == 0

    for i in range(4):
        q.submit(jnp.zeros((100, 3)), now=1.0)     # bucket 128
    (full,) = q.ready(now=1.0)                     # full batch, no deadline
    assert full.bucket == 128 and len(full.requests) == 3
    assert not full.deadline_flush and q.pending(128) == 1
    (rest,) = q.drain()
    assert len(rest.requests) == 1 and q.pending() == 0


# ---------------------------------------------------------------------------
# Engine: one shared engine (module scope) keeps compile cost bounded.
# ---------------------------------------------------------------------------

CLOCK = FakeClock()


@pytest.fixture(scope="module")
def engine():
    cfg = serve.ServeConfig(buckets=(64, 128), microbatch=2, max_wait_s=1.0,
                            variant="pointnet2", task="cls", th=32,
                            impl="xla")
    eng = serve.ServeEngine(cfg, clock=CLOCK)
    eng.warm()
    return eng


def cloud(n, step=0):
    pts, _ = synthetic.classification_batch(0, step, 1, n)
    return pts[0]


def test_mixed_stream_one_compile_per_bucket_impl(engine):
    """n drawn from 4 sizes across 2 buckets: exactly one trace per
    (bucket, impl) executable and per (bucket, th, strategy) plan."""
    sizes = [50, 64, 100, 128, 40, 120]
    rids = [engine.submit(cloud(n, i), now=CLOCK()) for i, n in
            enumerate(sizes)]
    engine.step()
    engine.flush()
    for rid in rids:
        assert engine.results[rid].shape == (engine.cfg.num_classes,)
    traces = engine.plans.traces
    assert sorted(k[1] for k in traces if k[0] == "serve") == [64, 128]
    assert sorted(k[1] for k in traces if k[0] == "plan") == [64, 128]
    assert all(v == 1 for v in traces.values()), dict(traces)


def test_plan_cache_hit_on_second_request(engine):
    hits0 = sum(engine.plans.hits.values())
    traces0 = dict(engine.plans.traces)
    engine.submit(cloud(60), now=CLOCK())
    engine.submit(cloud(64), now=CLOCK())
    engine.step()
    assert sum(engine.plans.hits.values()) > hits0      # warm executables
    assert dict(engine.plans.traces) == traces0         # ... no new traces


def test_deadline_flush_partial_microbatch(engine):
    """One pending request (microbatch=2) dispatches only once its
    deadline passes; the padded partial batch reuses the executable."""
    traces0 = dict(engine.plans.traces)
    CLOCK.t = 100.0
    rid = engine.submit(cloud(50, step=7), now=CLOCK())
    assert engine.step() == []                  # partial, deadline not hit
    CLOCK.t = 100.5
    assert engine.step() == []
    CLOCK.t = 101.25                            # waited 1.25 >= 1.0
    assert engine.step() == [rid]
    assert dict(engine.plans.traces) == traces0  # pad slots, same shapes
    lat, _ = engine._lat[64][-1]
    assert lat == pytest.approx(1.25)
    # the padded forward equals a fresh direct forward of the same cloud
    pc, pv = kops.pad_points(jnp.asarray(cloud(50, step=7)), 64)
    direct = np.asarray(pnn.apply(engine.params, engine._model_cfg(64), pc,
                                  valid=pv))
    np.testing.assert_allclose(engine.results[rid], direct, rtol=1e-5,
                               atol=1e-5)
    # pop-on-read: take() hands the result over exactly once
    assert engine.take(rid) is not None and engine.take(rid) is None


def test_stats_report_percentiles_and_throughput(engine):
    st = engine.stats()
    assert st["impl"] == "xla" and st["served"] >= 9
    for b in (64, 128):
        row = st["buckets"][b]
        assert row["p50_ms"] <= row["p95_ms"] <= row["p99_ms"]
        assert row["count"] > 0 and row["compile_s"] > 0
    assert st["clouds_per_s"] > 0 and st["mpts_per_s"] > 0
    assert st["plan_cache"]["executables"] == 4


def test_impl_is_part_of_the_executable_key():
    """A pallas engine compiles its own (bucket, "pallas") executable,
    once, with the impl pinned at construction (not read per call)."""
    cfg = serve.ServeConfig(buckets=(64,), microbatch=1, max_wait_s=0.0,
                            variant="pointnet2", task="cls", th=32,
                            impl="pallas")
    eng = serve.ServeEngine(cfg)
    for i, n in enumerate([48, 64]):
        eng.submit(cloud(n, i))
        eng.step()
    assert ("serve", 64, "pallas") in eng.plans
    assert all(v == 1 for v in eng.plans.traces.values())
    assert eng.results[0].shape == (cfg.num_classes,)


def test_injected_clock_latencies_exact():
    """Clock-domain regression: latencies and wall_s must live entirely in
    the caller's injected clock domain — _execute used to stamp t_done
    from the engine's real clock even when submit/step carried ``now``,
    mixing domains whenever a logical clock was injected."""
    cfg = serve.ServeConfig(buckets=(64,), microbatch=2, max_wait_s=1.0,
                            variant="pointnet2", task="cls", th=32,
                            impl="xla")
    eng = serve.ServeEngine(cfg)   # default (real) clock, never consulted
    eng.warm()
    r1 = eng.submit(cloud(40, 0), now=10.0)
    r2 = eng.submit(cloud(64, 1), now=10.5)
    assert sorted(eng.step(now=12.0)) == [r1, r2]     # full batch
    st = eng.stats()
    row = st["buckets"][64]
    assert row["p50_ms"] == pytest.approx(1.75e3)     # (2.0 + 1.5) / 2
    assert row["p99_ms"] == pytest.approx(2.0e3 - 0.25e3 * 0.02)
    assert st["wall_s"] == pytest.approx(2.0)         # 12.0 - 10.0
    assert st["clouds_per_s"] == pytest.approx(1.0)

    # flush(now=) threads the injected time the same way
    r3 = eng.submit(cloud(50, 2), now=20.0)
    assert eng.flush(now=23.0) == [r3]
    lat, _ = eng._lat[64][-1]
    assert lat == pytest.approx(3.0)
    assert eng.stats()["wall_s"] == pytest.approx(13.0)


def test_throughput_none_until_first_completion():
    """A submit-only stream has no completed window: stats() must report
    None throughput rather than dividing by the 1e-9 clamp (which turned
    an idle engine into an absurd clouds/s figure)."""
    cfg = serve.ServeConfig(buckets=(64,), microbatch=4, max_wait_s=60.0,
                            variant="pointnet2", task="cls", th=32,
                            impl="xla")
    eng = serve.ServeEngine(cfg, clock=FakeClock(5.0))
    assert eng.stats()["clouds_per_s"] is None        # nothing at all
    eng.submit(cloud(40))
    assert eng.step() == []                           # partial, no deadline
    st = eng.stats()
    assert st["wall_s"] is None
    assert st["clouds_per_s"] is None and st["mpts_per_s"] is None
    assert st["buckets"] == {}
    # a zero-width window (batch completed at the instant of its submit,
    # injected clock) is still "unknown", not a clamp-divided absurdity
    eng.flush(now=5.0)
    st = eng.stats()
    assert st["served"] == 1 and st["clouds_per_s"] is None
    assert st["wall_s"] is None
    # once the window has width the numbers come back
    eng.submit(cloud(30), now=5.5)
    assert eng.flush(now=6.0) != []
    st = eng.stats()
    assert st["clouds_per_s"] == pytest.approx(2.0)   # 2 clouds / 1.0 s
    assert st["buckets"][64]["clouds_per_s"] == st["clouds_per_s"]


def test_mesh_dispatch_matches_single_device():
    """mesh="auto" (elastic mesh over host devices, fit_specs-fitted
    microbatch sharding) returns the same logits as the mesh-free path."""
    kw = dict(buckets=(64,), microbatch=2, max_wait_s=0.0,
              variant="pointnet2", task="cls", th=32, impl="xla")
    eng_m = serve.ServeEngine(serve.ServeConfig(mesh="auto", **kw))
    eng_s = serve.ServeEngine(serve.ServeConfig(**kw))
    assert eng_m.mesh is not None
    for eng in (eng_m, eng_s):
        for i, n in enumerate([40, 64, 50]):
            eng.submit(cloud(n, i))
            eng.step()
        eng.flush()
    for rid in eng_s.results:
        np.testing.assert_allclose(eng_m.results[rid], eng_s.results[rid],
                                   rtol=1e-5, atol=1e-5)

"""Hypothesis property tests for the partition engine and the BPPO
pipeline.  This module needs the optional ``hypothesis`` test dependency
(``pip install -e .[test]``); where it is absent only these property tests
skip — the deterministic oracle tests in test_fractal.py / test_bppo.py
still run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro import core  # noqa: E402
from repro.core import fractal as fr  # noqa: E402

from test_fractal import check_invariants  # noqa: E402

jax.config.update("jax_platform_name", "cpu")


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([37, 101, 256, 333]),
       st.sampled_from([8, 16, 64]))
def test_property_random_clouds(seed, n, th):
    rng = np.random.default_rng(seed)
    pts = jnp.asarray(rng.normal(0, 1, (n, 3)).astype(np.float32))
    part = core.partition(pts, th=th)
    check_invariants(pts, part, th, fr.FRACTAL)


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10_000))
def test_property_padded_clouds(seed):
    rng = np.random.default_rng(seed)
    n, nv = 512, int(rng.integers(10, 512))
    pts = jnp.asarray(rng.normal(0, 1, (n, 3)).astype(np.float32))
    valid = jnp.arange(n) < nv
    part = core.partition(pts, valid, th=32)
    vp = np.asarray(part.valid)
    perm = np.asarray(part.perm)
    assert set(perm[vp].tolist()) == set(range(nv))
    check_invariants(pts, part, 32, fr.FRACTAL)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 1000), st.sampled_from([0.125, 0.25, 0.5]))
def test_property_pipeline_shapes_and_masks(seed, rate):
    rng = np.random.default_rng(seed)
    n = 512
    pts = jnp.asarray(rng.normal(0, 1, (n, 3)).astype(np.float32))
    part = core.partition(pts, th=32)
    samp = core.blockwise_fps(part, rate=rate, k_out=int(n * rate), bs=32)
    nb = core.blockwise_ball_query(part, samp, radius=0.4, num=8, w=64)
    assert samp.idx.shape == (int(n * rate),)
    assert nb.idx.shape == (int(n * rate), 8)
    sval = np.asarray(samp.valid)
    # every valid sample has >=1 neighbor (itself)
    assert (np.asarray(nb.cnt)[sval] >= 1).all()
    # invalid sample slots have no neighbors marked
    assert not np.asarray(nb.mask)[~sval].any()

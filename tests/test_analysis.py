"""Tests for repro.analysis — the contract linter + abstract checker.

Three layers:

* **fixtures** — a good/bad source pair per rule under
  ``tests/fixtures/lint/``; bad must fire exactly its rule, good must be
  clean, and the CLI exit codes must gate accordingly.
* **self-check** — the live ``src/repro`` tree lints clean (the property
  CI enforces), and a mutation smoke-test proves the linter would have
  caught the PR-5 clock-mixing bug if reintroduced in serve/engine.py.
* **abstract** — the eval_shape interface matrix passes on the real ops
  and each ABS rule fires on a deliberately broken synthetic OpCase.
"""
from pathlib import Path

import pytest

from repro.analysis import abstract, cli, walker, zones
from repro.analysis.report import Finding, sort_findings, summarize

FIXTURES = Path(__file__).parent / "fixtures" / "lint"

BAD = [
    ("clk001_bad.py", "CLK001"),
    ("clk002_bad.py", "CLK002"),
    ("clk003_bad.py", "CLK003"),
    ("trc001_bad.py", "TRC001"),
    ("trc002_bad.py", "TRC002"),
    ("trc003_bad.py", "TRC003"),
    ("vjp001_bad.py", "VJP001"),
    ("dsp001_bad.py", "DSP001"),
    ("dsp002_bad.py", "DSP002"),
    ("pragma_unused.py", "PRG001"),
]

GOOD = ["clk001_good.py", "clk002_good.py", "clk003_good.py",
        "trc001_good.py", "trc002_good.py", "trc003_good.py",
        "vjp001_good.py", "dsp001_good.py", "dsp002_good.py",
        "pragma_ok.py"]


# ---------------------------------------------------------------- fixtures

@pytest.mark.parametrize("name,rule", BAD)
def test_bad_fixture_fires_its_rule(name, rule):
    findings = walker.lint_paths([FIXTURES / name])
    assert rule in {f.rule for f in findings}, \
        f"{name}: expected {rule}, got {sorted({f.rule for f in findings})}"
    for f in findings:
        assert f.path.endswith(name) and f.line >= 1


@pytest.mark.parametrize("name", GOOD)
def test_good_fixture_is_clean(name):
    findings = walker.lint_paths([FIXTURES / name])
    assert findings == [], [f.format() for f in findings]


@pytest.mark.parametrize("name,rule", BAD)
def test_cli_fails_on_bad_fixture(name, rule, capsys):
    # --strict so the WARN-severity CLK003 fixture gates too.
    assert cli.main([str(FIXTURES / name), "--strict"]) == 1
    out = capsys.readouterr().out
    assert rule in out and name in out


@pytest.mark.parametrize("name", GOOD)
def test_cli_passes_on_good_fixture(name, capsys):
    assert cli.main([str(FIXTURES / name), "--strict"]) == 0


def test_warnings_gate_only_under_strict():
    bad = str(FIXTURES / "clk003_bad.py")
    assert cli.main([bad]) == 0          # CLK003 is WARN severity
    assert cli.main([bad, "--strict"]) == 1


def test_rules_flag_narrows_the_run():
    bad = str(FIXTURES / "clk001_bad.py")
    assert cli.main([bad, "--rules", "CLK001"]) == 1
    assert cli.main([bad, "--rules", "TRC001"]) == 0


def test_list_rules(capsys):
    assert cli.main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule in ("CLK001", "TRC002", "VJP001", "ABS001", "PRG001"):
        assert rule in out


# -------------------------------------------------------------- suppression

def test_pragma_suppresses_only_its_line_and_rule():
    text = (FIXTURES / "pragma_ok.py").read_text()
    assert walker.lint_source(text, "pragma_ok.py", zone="train") == []
    # The same pragma does not excuse a different rule id.
    swapped = text.replace("disable=CLK003", "disable=TRC001")
    findings = walker.lint_source(swapped, "pragma_ok.py", zone="train")
    assert {f.rule for f in findings} == {"CLK003", "PRG001"}


def test_pragma_in_docstring_is_not_a_pragma():
    text = ('"""Docs may quote `# repolint: disable=CLK003` freely."""\n'
            "X = 1\n")
    assert walker.lint_source(text, "doc.py", zone="train") == []


# ----------------------------------------------------------- zones / report

def test_zone_of_paths():
    assert zones.zone_of("src/repro/serve/engine.py") == "serve"
    assert zones.zone_of("src/repro/kernels/ops.py") == "kernels.ops"
    assert zones.zone_of("src/repro/kernels/fps.py") == "kernels"
    assert zones.zone_of("somewhere/else.py") == "other"
    assert zones.zone_of("f.py", "# repolint: zone=scene") == "scene"


def test_finding_format_and_sort():
    a = Finding(path="b.py", line=3, rule="CLK001", severity="error",
                message="m")
    b = Finding(path="a.py", line=9, rule="CLK003", severity="warn",
                message="m")
    assert a.format() == "b.py:3 CLK001 error m"
    assert sort_findings([a, b])[0].path == "a.py"
    assert "1 error" in summarize([a, b])


# ------------------------------------------------------ live-tree self-check

def test_live_tree_lints_clean():
    findings = walker.lint_tree()
    assert findings == [], "\n".join(f.format() for f in findings)


def test_mutation_reintroducing_wall_clock_is_caught():
    """The PR-5 bug class: a wall-clock read sneaking back into the serving
    engine must trip CLK001."""
    path = walker.repo_root() / "src" / "repro" / "serve" / "engine.py"
    clean = path.read_text()
    baseline = walker.lint_source(clean, "src/repro/serve/engine.py")
    assert baseline == [], [f.format() for f in baseline]

    mutated = clean + ("\n\ndef _leaky_latency(start):\n"
                       "    return time.time() - start\n")
    findings = walker.lint_source(mutated, "src/repro/serve/engine.py")
    assert "CLK001" in {f.rule for f in findings}


# ------------------------------------------------------------ abstract layer

def test_abstract_matrix_is_clean_on_live_ops():
    findings = abstract.run_interface_checks(matrix=(abstract.MATRIX[0],))
    assert findings == [], "\n".join(f.format() for f in findings)


def _aval(shape):
    import jax
    import jax.numpy as jnp

    return jax.ShapeDtypeStruct(shape, jnp.float32)


def test_abstract_catches_impl_divergence_and_bad_tiles():
    import jax

    case = abstract.OpCase(
        name="bogus", wrapper=abstract.run_interface_checks,
        make_inputs=lambda d: (_aval((4, 8)),),
        # pallas path drops a row: ABS001 must flag the parity break.
        call=lambda inp, impl, chunk, d: jax.eval_shape(
            (lambda x: x) if impl == "xla" else (lambda x: x[:2]), *inp),
        oracle=lambda d: jax.eval_shape(lambda x: x, *(_aval((4, 8)),)),
        tiles=lambda d: [],
    )
    rules = {f.rule for f in abstract.check_case(case, {})}
    assert rules == {"ABS001"}


def test_abstract_catches_oracle_mismatch_and_tile_violations():
    import jax

    case = abstract.OpCase(
        name="bogus", wrapper=abstract.run_interface_checks,
        make_inputs=lambda d: (_aval((4, 8)),),
        call=lambda inp, impl, chunk, d: jax.eval_shape(lambda x: x, *inp),
        # oracle says (4, 9): ABS002.
        oracle=lambda d: jax.eval_shape(lambda: __import__("jax").numpy
                                        .zeros((4, 9))),
        tiles=lambda d: [
            # block does not divide array: ABS003.
            abstract.Tile("ragged", (4, 256), (3, 256)),
            # 20 MiB single tile: ABS004.
            abstract.Tile("huge", (2048, 2560), (2048, 2560)),
            # non-ref intermediates are exempt from divisibility...
            abstract.Tile("scratch", (4, 200), (3, 200), ref=False),
        ],
    )
    rules = {f.rule for f in abstract.check_case(case, {})}
    assert rules == {"ABS002", "ABS003", "ABS004"}


def test_tile_nbytes():
    t = abstract.Tile("t", (8, 128), (8, 128))
    assert t.nbytes == 8 * 128 * 4


# -------------------------------------------------------- bench drift gate

def _check_bench():
    import importlib.util

    spec = importlib.util.spec_from_file_location(
        "check_bench",
        Path(__file__).parents[1] / "scripts" / "check_bench.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_check_bench_compare_gates_regressions():
    cb = _check_bench()
    old = {"slow_op": 1000.0, "tiny_op": 50.0, "gone_op": 800.0}
    new = {"slow_op": 1900.0, "tiny_op": 120.0, "fresh_op": 900.0}
    failures, notes = cb.compare(new, old, tolerance=1.5, min_us=200.0)
    # slow_op regressed 1.9x > 1.5x; gone_op vanished; tiny_op jitter is
    # clamped under the floor; fresh_op has no baseline -> note only.
    assert len(failures) == 2
    assert any("slow_op" in f for f in failures)
    assert any("gone_op" in f for f in failures)
    assert any("fresh_op" in n for n in notes)
    assert not any("tiny_op" in f for f in failures)


def test_check_bench_floor_still_catches_blowups():
    cb = _check_bench()
    failures, _ = cb.compare({"op": 5000.0}, {"op": 100.0},
                             tolerance=1.5, min_us=200.0)
    assert failures, "a sub-floor row regressing 50x must still gate"


def test_check_bench_cli_roundtrip(tmp_path):
    cb = _check_bench()
    payload = {"suite": "demo", "rows": [
        {"name": "op", "us_per_call": 1000.0, "derived": ""}]}
    fresh = tmp_path / "BENCH_demo.json"
    fresh.write_text(__import__("json").dumps(payload))
    hist = tmp_path / "history"
    # First run seeds the snapshot, second run compares clean.
    assert cb.main([str(fresh), "--history", str(hist)]) == 0
    assert (hist / "BENCH_demo.json").exists()
    assert cb.main([str(fresh), "--history", str(hist)]) == 0
    # A 10x regression against the snapshot gates.
    payload["rows"][0]["us_per_call"] = 10000.0
    fresh.write_text(__import__("json").dumps(payload))
    assert cb.main([str(fresh), "--history", str(hist)]) == 1
    # --update blesses the new numbers.
    assert cb.main([str(fresh), "--history", str(hist), "--update"]) == 0
    assert cb.main([str(fresh), "--history", str(hist)]) == 0

"""Gradient contract of the execute-phase dispatch ops (kernels/vjp.py,
docs/DESIGN.md §4): gather's VJP matches the ref oracle's, index producers
carry zero cotangents, ``jax.grad`` through ``pnn.apply`` agrees between
``impl="pallas"`` (interpret) and ``impl="xla"`` at 1e-4, and a multi-step
fine-tune on an 8-device host mesh lowers the loss."""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import synthetic
from repro.kernels import ops, ref
from repro.models import pnn

jax.config.update("jax_platform_name", "cpu")

IMPLS = ["xla", "pallas"]


def blocks(seed, nb, bs, max_valid=None):
    rng = np.random.default_rng(seed)
    coords = rng.normal(0, 1, (nb, bs, 3)).astype(np.float32)
    nvalid = rng.integers(1, (max_valid or bs) + 1, nb)
    mask = np.arange(bs)[None, :] < nvalid[:, None]
    return jnp.asarray(coords), jnp.asarray(mask)


# ---------------------------------------------------------------------------
# Per-op VJPs against jax.vjp of the ref oracle.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("chunk", [None, 2])
def test_gather_vjp_matches_ref_oracle(impl, chunk):
    """d(window_feats) through the dispatch layer == jax.vjp of the jnp
    oracle — including out-of-range idx rows, which fetched zeros forward
    and must receive nothing backward."""
    rng = np.random.default_rng(0)
    feats = jnp.asarray(rng.normal(0, 1, (3, 40, 9)).astype(np.float32))
    idx = jnp.asarray(rng.integers(-5, 50, (3, 17)), jnp.int32)  # oob both
    g = jnp.asarray(rng.normal(0, 1, (3, 17, 9)).astype(np.float32))

    ro, rvjp = jax.vjp(lambda f: ref.gather_blocks(f, idx), feats)
    (rg,) = rvjp(g)
    o, vjp = jax.vjp(
        lambda f: ops.gather_blocks(f, idx, impl=impl, chunk=chunk), feats)
    (df,) = vjp(g)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ro),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(df), np.asarray(rg),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("impl", IMPLS)
def test_index_producers_zero_cotangents(impl):
    """FPS / ball query / kNN are index producers: every output —
    including the float d2 — carries a zero cotangent to every input."""
    coords, mask = blocks(1, 2, 40)

    d2 = lambda c: ops.knn_blocks(c, c, mask, k=3, impl=impl)[1]
    g = jax.grad(lambda c: jnp.sum(d2(c)))(coords)
    assert float(jnp.abs(g).sum()) == 0.0

    bq = lambda c: ops.ball_query_blocks(c, mask, c, mask, radius=0.7,
                                         num=4, impl=impl)[1]
    g = jax.grad(lambda c: jnp.sum(bq(c)))(coords)
    assert float(jnp.abs(g).sum()) == 0.0

    # fps output is integer (tangent type float0): grad through a loss
    # that *uses* the indices must flow only through the explicit gather,
    # not through the selection itself.  The selection is discrete, so
    # the grad is exactly the oracle of "gather at the selected slots".
    def loss(c):
        idx = ops.fps_blocks(c, mask, k=4, impl=impl)
        picked = jnp.take_along_axis(c, idx[..., None], axis=1)
        return jnp.sum(picked)

    g = jax.grad(loss)(coords)
    assert np.isfinite(np.asarray(g)).all()
    idx = ops.fps_blocks(coords, mask, k=4, impl=impl)
    oracle = jax.grad(lambda c: jnp.sum(jnp.take_along_axis(
        c, idx[..., None], axis=1)))(coords)
    np.testing.assert_allclose(np.asarray(g), np.asarray(oracle),
                               rtol=1e-6)


@pytest.mark.parametrize("impl", IMPLS)
def test_fractal_level_zero_cotangents(impl):
    coords, mask = blocks(2, 3, 33)
    mid = jnp.zeros((3,), jnp.float32)

    def f(c, m):
        _, _, stats = ops.fractal_level_blocks(c, m, mid, da=0, db=1,
                                               impl=impl)
        return jnp.sum(jnp.where(jnp.abs(stats) < 1e30, stats, 0.0))

    g = jax.grad(f)(coords, mask.astype(jnp.float32))
    assert float(jnp.abs(g).sum()) == 0.0


# ---------------------------------------------------------------------------
# End-to-end: grad through pnn.apply, pallas (interpret) vs xla.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("task,n,th", [("cls", 192, 32), ("seg", 256, 64)])
def test_pnn_grad_parity(task, n, th):
    """jax.value_and_grad of a PNN loss compiles and runs with
    impl="pallas" (no xla fallback) and the grads agree with the oracle
    backend at 1e-4 — cls and seg presets."""
    cfg = pnn.PNNConfig(variant="pointnet2", task=task, n_points=n,
                        point_ops="bppo", th=th)
    params = pnn.init(jax.random.PRNGKey(0), cfg)
    batch = (synthetic.classification_batch if task == "cls"
             else synthetic.segmentation_batch)
    pts, labels = batch(0, 0, 1, n)

    def loss(p, impl):
        mcfg = dataclasses.replace(cfg, impl=impl)
        logits = pnn.apply(p, mcfg, pts[0])
        ll = jax.nn.log_softmax(logits)
        if task == "cls":
            return -ll[labels[0]]
        return -jnp.mean(jnp.take_along_axis(ll, labels[0][:, None],
                                             axis=-1))

    vp, gp = jax.jit(jax.value_and_grad(
        lambda p: loss(p, "pallas")))(params)
    vx, gx = jax.jit(jax.value_and_grad(lambda p: loss(p, "xla")))(params)
    np.testing.assert_allclose(float(vp), float(vx), rtol=1e-4, atol=1e-4)
    for (kp, a), (_, b) in zip(jax.tree_util.tree_leaves_with_path(gp),
                               jax.tree_util.tree_leaves_with_path(gx)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=jax.tree_util.keystr(kp))
    norms = [float(jnp.abs(x).sum()) for x in jax.tree.leaves(gp)]
    assert sum(v > 0 for v in norms) > len(norms) * 0.7, norms


def test_pnn_train_step_runs_pallas():
    """One full AdamW fine-tune step with impl="pallas" end to end (the
    escape hatch is gone: no wrap-with-xla needed under jax.grad)."""
    from repro.train import pnn as train_pnn

    cfg = train_pnn.TrainConfig(preset="pointnet2_cls", n_points=128,
                                th=32, batch=2, steps=1, impl="pallas")
    mcfg = train_pnn.model_config(cfg)
    assert mcfg.impl == "pallas"
    params, _, info = train_pnn.fit(cfg, log=lambda *_: None)
    assert len(info["history"]) == 1
    assert np.isfinite(info["history"][0]["loss"])


# ---------------------------------------------------------------------------
# Multi-step fine-tune on the 8-device host mesh (subprocess: device count
# must be set before jax initializes).
# ---------------------------------------------------------------------------

TRAIN_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    from repro.train import pnn as train_pnn

    cfg = train_pnn.TrainConfig(preset="pointnet2_cls", n_points=128,
                                th=32, batch=8, steps=6, lr=3e-3,
                                impl="xla", mesh="auto")
    params, _, info = train_pnn.fit(cfg, log=lambda *_: None)
    h = info["history"]
    print(json.dumps({
        "n_dev": len(jax.devices()),
        "losses": [s["loss"] for s in h],
    }))
""")


def test_multidevice_finetune_lowers_loss():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", TRAIN_PROG],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    data = json.loads(out.stdout.strip().splitlines()[-1])
    assert data["n_dev"] == 8
    losses = data["losses"]
    assert len(losses) == 6 and all(np.isfinite(losses))
    assert losses[-1] < losses[0], losses

"""Unit tests for dist helpers: the dispatch layer's leaf_chunks
padding/reshape invariants and logical.lc inside vs outside a
logical_rules context."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import logical
from repro.kernels import ops as kops

jax.config.update("jax_platform_name", "cpu")


class TestLeafChunks:
    def test_even_split_no_padding(self):
        a = jnp.arange(12.0).reshape(12, 1)
        (c,), ml = kops.leaf_chunks((a,), 4)
        assert ml == 12
        assert c.shape == (3, 4, 1)
        np.testing.assert_array_equal(np.asarray(c.reshape(12, 1)),
                                      np.asarray(a))

    def test_odd_leaf_count_pads_with_zeros(self):
        a = jnp.arange(1.0, 8.0)          # 7 leaves, chunk 3 -> pad 2
        (c,), ml = kops.leaf_chunks((a,), 3)
        assert ml == 7
        assert c.shape == (3, 3)
        flat = np.asarray(c.reshape(-1))
        np.testing.assert_array_equal(flat[:7], np.arange(1.0, 8.0))
        np.testing.assert_array_equal(flat[7:], 0.0)

    def test_chunk_larger_than_ml(self):
        a = jnp.ones((5, 2, 3))
        (c,), ml = kops.leaf_chunks((a,), 8)
        assert ml == 5
        assert c.shape == (1, 8, 2, 3)
        # trailing dims are never padded
        np.testing.assert_array_equal(np.asarray(c[0, :5]), np.asarray(a))
        np.testing.assert_array_equal(np.asarray(c[0, 5:]), 0.0)

    def test_multiple_arrays_share_layout(self):
        arrays = (jnp.arange(10.0), jnp.ones((10, 4), bool))
        out, ml = kops.leaf_chunks(arrays, 4)
        assert ml == 10
        assert out[0].shape == (3, 4) and out[1].shape == (3, 4, 4)
        # un-chunk + strip padding round-trips every array
        for orig, chunked in zip(arrays, out):
            back = chunked.reshape(-1, *chunked.shape[2:])[:ml]
            np.testing.assert_array_equal(np.asarray(back), np.asarray(orig))

    def test_roundtrip_matches_chunked_map(self):
        # the dispatch usage pattern: lax.map over chunks == direct compute
        a = jnp.arange(7.0)
        chunks, ml = kops.leaf_chunks((a,), 2)
        y = jax.lax.map(lambda s: s[0] * 2.0, chunks)
        np.testing.assert_array_equal(np.asarray(y.reshape(-1)[:ml]),
                                      np.asarray(a) * 2.0)

class TestLogicalConstraint:
    def test_lc_outside_context_is_identity(self):
        x = jnp.ones((4, 6))
        assert logical.lc(x, "batch", "ff") is x

    def test_lc_inside_context_constrains(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        x = jnp.arange(24.0).reshape(4, 6)
        with logical.logical_rules(mesh, logical.RULES_V0):
            y = jax.jit(lambda v: logical.lc(v, "batch", "ff") * 1.0)(x)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))

    def test_lc_rank_mismatch_raises(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        with logical.logical_rules(mesh, logical.RULES_V0):
            with pytest.raises(ValueError, match="rank"):
                logical.lc(jnp.ones((2, 2)), "batch")

    def test_priority_resolves_mesh_axis_conflicts(self):
        # seq_shard and heads both map to "model"; seq_shard has priority,
        # heads replicates (sequence-parallel v0 attention).
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        with logical.logical_rules(mesh, logical.RULES_V0):
            assert logical.spec(("batch", "seq_shard", "heads", None)) == \
                P(("data",), "model", None, None)
            assert logical.spec(("batch", "heads", None, "seq_shard")) == \
                P(("data",), None, None, "model")

    def test_axis_size_and_rules_with(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        assert logical.axis_size("batch") == 1  # no context
        rules = logical.rules_with(points="model", ff=None)
        with logical.logical_rules(mesh, rules):
            assert logical.spec(("points",)) == P("model")
            assert logical.spec(("ff",)) == P(None)
            assert logical.axis_size("batch") == 1  # (1,1) mesh

    def test_nested_contexts_restore(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        with logical.logical_rules(mesh, logical.RULES_V0):
            with logical.logical_rules(mesh, logical.rules_with(ff=None)):
                assert logical.spec(("ff",)) == P(None)
            assert logical.spec(("ff",)) == P("model")
        assert logical.current() is None

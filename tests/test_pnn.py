"""PNN model tests: both point-op modes, both tasks, training signal."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import synthetic
from repro.models import pnn
from repro.train import optimizer as opt_lib

jax.config.update("jax_platform_name", "cpu")
KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("ops", ["global", "bppo"])
@pytest.mark.parametrize("variant", ["pointnet2", "pointnext",
                                     "pointvector"])
def test_seg_forward(ops, variant):
    cfg = pnn.PNNConfig(variant=variant, task="seg", n_points=384,
                        point_ops=ops, th=64)
    params = pnn.init(KEY, cfg)
    pts, labels = synthetic.segmentation_batch(0, 0, 2, 384)
    out = jax.jit(jax.vmap(lambda c: pnn.apply(params, cfg, c)))(pts)
    assert out.shape == (2, 384, cfg.num_classes)
    assert jnp.isfinite(out).all()


@pytest.mark.parametrize("ops", ["global", "bppo"])
def test_cls_forward(ops):
    cfg = pnn.pointnet2_cls(n=256, point_ops=ops, th=32)
    params = pnn.init(KEY, cfg)
    pts, labels = synthetic.classification_batch(0, 0, 2, 256)
    out = jax.jit(jax.vmap(lambda c: pnn.apply(params, cfg, c)))(pts)
    assert out.shape == (2, synthetic.NUM_SHAPES)
    assert jnp.isfinite(out).all()


def test_leaf_chunked_equals_unchunked():
    cfg_a = pnn.pointnext_seg(n=384, point_ops="bppo", th=64)
    import dataclasses
    cfg_b = dataclasses.replace(cfg_a, leaf_chunk=4)
    params = pnn.init(KEY, cfg_a)
    pts, _ = synthetic.segmentation_batch(1, 0, 1, 384)
    a = pnn.apply(params, cfg_a, pts[0])
    b = pnn.apply(params, cfg_b, pts[0])
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("ops", ["global", "bppo"])
def test_training_signal(ops):
    """A few steps on a fixed batch must reduce loss in both modes (the
    paper's trainability claim at smoke scale)."""
    cfg = pnn.pointnet2_cls(n=192, point_ops=ops, th=32)
    params = pnn.init(KEY, cfg)
    opt_cfg = opt_lib.OptConfig(lr=3e-3, warmup=0, total_steps=20,
                                weight_decay=0.0)
    opt = opt_lib.init(params)
    pts, labels = synthetic.classification_batch(0, 0, 8, 192)

    @jax.jit
    def step(params, opt):
        def loss_f(p):
            logits = jax.vmap(lambda c: pnn.apply(p, cfg, c))(pts)
            ll = jax.nn.log_softmax(logits)
            return -jnp.mean(jnp.take_along_axis(ll, labels[:, None], 1))

        loss, grads = jax.value_and_grad(loss_f)(params)
        params, opt, _ = opt_lib.update(opt_cfg, grads, opt, params)
        return params, opt, loss

    losses = []
    for _ in range(15):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.8, losses


def test_gradients_flow_through_bppo():
    cfg = pnn.pointnet2_cls(n=192, point_ops="bppo", th=32)
    params = pnn.init(KEY, cfg)
    pts, _ = synthetic.classification_batch(2, 0, 1, 192)
    g = jax.grad(lambda p: jnp.sum(pnn.apply(p, cfg, pts[0])))(params)
    norms = [float(jnp.abs(x).sum()) for x in jax.tree.leaves(g)]
    assert all(np.isfinite(norms))
    assert sum(n > 0 for n in norms) > len(norms) * 0.7

"""Pallas kernels (interpret mode) vs pure-jnp oracles, swept over shapes."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref as kref

jax.config.update("jax_platform_name", "cpu")


def blocks(seed, nb, bs, dtype=jnp.float32, frac_valid=0.8):
    rng = np.random.default_rng(seed)
    coords = rng.normal(0, 1, (nb, bs, 3)).astype(np.float32)
    nvalid = rng.integers(max(1, int(frac_valid * bs) - 4), bs + 1, nb)
    mask = np.arange(bs)[None, :] < nvalid[:, None]
    return jnp.asarray(coords, dtype), jnp.asarray(mask)


@pytest.mark.parametrize("nb,bs,k", [(4, 64, 16), (2, 128, 8), (7, 200, 5),
                                     (1, 256, 64), (3, 96, 1)])
def test_fps_kernel_matches_ref(nb, bs, k):
    coords, mask = blocks(0, nb, bs)
    a = ops.fps_blocks(coords, mask, k=k, impl="pallas")
    b = ops.fps_blocks(coords, mask, k=k, impl="xla")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fps_kernel_dtypes(dtype):
    coords, mask = blocks(1, 3, 128, dtype)
    a = ops.fps_blocks(coords, mask, k=8, impl="pallas")
    b = ops.fps_blocks(coords, mask, k=8, impl="xla")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fps_kernel_samples_valid_first():
    coords, mask = blocks(2, 4, 64, frac_valid=0.4)
    idx = np.asarray(ops.fps_blocks(coords, mask, k=8, impl="pallas"))
    m = np.asarray(mask)
    for b in range(4):
        nv = m[b].sum()
        take = min(8, nv)
        assert m[b][idx[b][:take]].all(), "sampled an invalid point"
        assert len(np.unique(idx[b][:take])) == take, "duplicate sample"


@pytest.mark.parametrize("nb,kc,w,num", [(3, 16, 128, 8), (2, 32, 256, 16),
                                         (5, 8, 64, 4), (1, 64, 512, 32)])
def test_ball_query_kernel_matches_ref(nb, kc, w, num):
    rng = np.random.default_rng(3)
    win, wmask = blocks(4, nb, w)
    ci = rng.integers(0, w, (nb, kc))
    centers = jnp.take_along_axis(win, jnp.asarray(ci)[..., None], axis=1)
    cmask = jnp.ones((nb, kc), bool)
    a_idx, a_d2, a_cnt = ops.ball_query_blocks(
        centers, cmask, win, wmask, radius=0.7, num=num, impl="pallas")
    b_idx, b_d2, b_cnt = ops.ball_query_blocks(
        centers, cmask, win, wmask, radius=0.7, num=num, impl="xla")
    np.testing.assert_array_equal(np.asarray(a_idx), np.asarray(b_idx))
    np.testing.assert_allclose(np.asarray(a_d2), np.asarray(b_d2),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(a_cnt), np.asarray(b_cnt))


def test_ball_query_semantics():
    # nearest-first, in-radius, count correct vs brute force numpy
    rng = np.random.default_rng(5)
    win, wmask = blocks(6, 2, 96)
    centers = win[:, :5, :]
    cmask = jnp.ones((2, 5), bool)
    idx, d2, cnt = ops.ball_query_blocks(centers, cmask, win, wmask,
                                         radius=0.9, num=8, impl="pallas")
    wn, mn = np.asarray(win), np.asarray(wmask)
    for b in range(2):
        for i in range(5):
            d = ((wn[b] - wn[b, i]) ** 2).sum(-1)
            d[~mn[b]] = np.inf
            true_cnt = int((d <= 0.81).sum())
            assert int(cnt[b, i]) == true_cnt
            order = np.argsort(d, kind="stable")[:8]
            got = np.asarray(idx[b, i])
            valid_k = min(8, true_cnt)
            np.testing.assert_array_equal(got[:valid_k], order[:valid_k])


@pytest.mark.parametrize("nb,q,w,k", [(3, 32, 128, 3), (2, 64, 96, 5),
                                      (1, 16, 256, 8)])
def test_knn_kernel_matches_ref(nb, q, w, k):
    win, wmask = blocks(7, nb, w)
    queries, _ = blocks(8, nb, q)
    a_idx, a_d2 = ops.knn_blocks(queries, win, wmask, k=k, impl="pallas")
    b_idx, b_d2 = ops.knn_blocks(queries, win, wmask, k=k, impl="xla")
    np.testing.assert_array_equal(np.asarray(a_idx), np.asarray(b_idx))
    np.testing.assert_allclose(np.asarray(a_d2), np.asarray(b_d2),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("nb,w,c,m", [(3, 64, 16, 20), (2, 128, 32, 64),
                                      (1, 96, 8, 7)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather_kernel_matches_ref(nb, w, c, m, dtype):
    rng = np.random.default_rng(9)
    feats = jnp.asarray(rng.normal(0, 1, (nb, w, c)), dtype)
    idx = jnp.asarray(rng.integers(0, w, (nb, m)), jnp.int32)
    a = ops.gather_blocks(feats, idx, impl="pallas")
    b = ops.gather_blocks(feats, idx, impl="xla")
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=1e-6)


@pytest.mark.parametrize("da,db", [(0, 1), (1, 2), (2, 0)])
def test_fractal_engine_kernel_matches_ref(da, db):
    coords, mask = blocks(10, 6, 160)
    mid = jnp.asarray(np.random.default_rng(11).normal(0, 0.5, (6,)),
                      jnp.float32)
    a = ops.fractal_level_blocks(coords, mask, mid, da=da, db=db,
                                 impl="pallas")
    b = ops.fractal_level_blocks(coords, mask, mid, da=da, db=db, impl="xla")
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-6)


def test_fractal_engine_pipelined_stats_enable_child_mids():
    """Fig. 9 pipeline: the child midpoints derived from the kernel's fused
    child-extrema equal what a fresh min/max traversal would compute."""
    coords, mask = blocks(12, 4, 128)
    x = np.asarray(coords)
    m = np.asarray(mask)
    mids0 = jnp.asarray(
        [(x[b][m[b], 0].max() + x[b][m[b], 0].min()) / 2 for b in range(4)],
        jnp.float32)
    side, lcnt, stats = ops.fractal_level_blocks(coords, mask, mids0,
                                                 da=0, db=1, impl="pallas")
    side = np.asarray(side)
    stats = np.asarray(stats)
    for b in range(4):
        left = m[b] & (side[b] == 0)
        right = m[b] & (side[b] == 1)
        if left.any():
            want = (x[b][left, 1].min() + x[b][left, 1].max()) / 2
            got = (stats[b, 0] + stats[b, 1]) / 2
            np.testing.assert_allclose(got, want, rtol=1e-6)
        if right.any():
            want = (x[b][right, 1].min() + x[b][right, 1].max()) / 2
            got = (stats[b, 2] + stats[b, 3]) / 2
            np.testing.assert_allclose(got, want, rtol=1e-6)

"""Training-substrate tests: optimizer, checkpoint/restart, fault injection,
straggler detection, gradient compression, elastic meshes."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist import compression, elastic
from repro.train import checkpoint as ckpt
from repro.train import loop as loop_lib
from repro.train import optimizer as opt_lib
from repro.train.monitor import HeartbeatFile, StepMonitor

jax.config.update("jax_platform_name", "cpu")
KEY = jax.random.PRNGKey(0)


class TestOptimizer:
    def test_quadratic_convergence(self):
        params = {"w": jnp.array([5.0, -3.0])}
        cfg = opt_lib.OptConfig(lr=0.2, warmup=0, total_steps=200,
                                weight_decay=0.0)
        opt = opt_lib.init(params)
        for _ in range(200):
            grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
            params, opt, _ = opt_lib.update(cfg, grads, opt, params)
        assert float(jnp.abs(params["w"]).max()) < 1e-2

    def test_warmup_and_cosine(self):
        cfg = opt_lib.OptConfig(lr=1.0, warmup=10, total_steps=100)
        assert float(opt_lib.schedule(cfg, jnp.int32(5))) == pytest.approx(0.5)
        assert float(opt_lib.schedule(cfg, jnp.int32(10))) == pytest.approx(1.0)
        assert float(opt_lib.schedule(cfg, jnp.int32(100))) == \
            pytest.approx(cfg.min_lr_frac, rel=1e-3)

    def test_clipping(self):
        params = {"w": jnp.zeros(3)}
        cfg = opt_lib.OptConfig(lr=1.0, warmup=0, clip_norm=1.0,
                                weight_decay=0.0)
        opt = opt_lib.init(params)
        _, _, m = opt_lib.update(cfg, {"w": jnp.full(3, 100.0)}, opt, params)
        assert float(m["grad_norm"]) > 100


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6).reshape(2, 3),
                "b": {"c": jnp.ones(4, jnp.bfloat16)}}
        ckpt.save(str(tmp_path), 7, tree, extra={"next_step": 8})
        assert ckpt.latest_step(str(tmp_path)) == 7
        restored, manifest = ckpt.restore(str(tmp_path), 7, tree)
        assert manifest["extra"]["next_step"] == 8
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(tree["a"]))
        assert restored["b"]["c"].dtype == jnp.bfloat16

    def test_gc_keeps_recent(self, tmp_path):
        tree = {"x": jnp.ones(2)}
        for s in (1, 2, 3, 4, 5):
            ckpt.save(str(tmp_path), s, tree, keep=2)
        assert ckpt.all_steps(str(tmp_path)) == [4, 5]

    def test_shape_mismatch_detected(self, tmp_path):
        ckpt.save(str(tmp_path), 1, {"x": jnp.ones(2)})
        with pytest.raises(ValueError):
            ckpt.restore(str(tmp_path), 1, {"x": jnp.ones(3)})

    def test_async_checkpointer(self, tmp_path):
        saver = ckpt.AsyncCheckpointer(str(tmp_path))
        saver.save(3, {"x": jnp.ones(2)})
        saver.wait()
        assert ckpt.latest_step(str(tmp_path)) == 3


def _toy_problem():
    target = jnp.asarray(np.random.default_rng(0).normal(0, 1, (8,)),
                         jnp.float32)

    def init_params():
        return {"w": jnp.zeros(8)}

    def next_batch(step):
        return target

    def train_step(params, opt_state, batch, return_grads=False):
        def loss_f(p):
            return jnp.sum((p["w"] - batch) ** 2)

        loss, grads = jax.value_and_grad(loss_f)(params)
        if return_grads:
            return grads, {"loss": loss}
        p, o, m = opt_lib.update(
            opt_lib.OptConfig(lr=0.1, warmup=0, weight_decay=0.0),
            grads, opt_state, params)
        return p, o, {"loss": loss, **m}

    return init_params, train_step, next_batch, target


class TestLoop:
    def test_trains_and_checkpoints(self, tmp_path):
        init_params, train_step, next_batch, target = _toy_problem()
        cfg = loop_lib.LoopConfig(total_steps=60, ckpt_dir=str(tmp_path),
                                  ckpt_every=20, log_every=1000)
        params, _, info = loop_lib.run(
            cfg, init_params=init_params, train_step=train_step,
            next_batch=next_batch,
            opt_cfg=opt_lib.OptConfig(lr=0.1, warmup=0, weight_decay=0.0))
        assert info["history"][-1]["loss"] < info["history"][0]["loss"]
        assert ckpt.latest_step(str(tmp_path)) == 60

    def test_crash_restart_resumes(self, tmp_path):
        init_params, train_step, next_batch, target = _toy_problem()
        cfg = loop_lib.LoopConfig(total_steps=50, ckpt_dir=str(tmp_path),
                                  ckpt_every=10, log_every=1000)
        with pytest.raises(RuntimeError, match="injected failure"):
            loop_lib.run(cfg, init_params=init_params,
                         train_step=train_step, next_batch=next_batch,
                         fail_at=35,
                         opt_cfg=opt_lib.OptConfig(lr=0.1, warmup=0,
                                                   weight_decay=0.0))
        # restart: resumes from step 31 (last ckpt at 30), finishes
        params, _, info = loop_lib.run(
            cfg, init_params=init_params, train_step=train_step,
            next_batch=next_batch,
            opt_cfg=opt_lib.OptConfig(lr=0.1, warmup=0, weight_decay=0.0))
        steps_run = [h["step"] for h in info["history"]]
        assert steps_run[0] == 31, "did not resume from checkpoint"
        assert steps_run[-1] == 49
        # converging (50 AdamW steps at lr=0.1 from a restored state)
        assert float(jnp.abs(params["w"] - target).max()) < 0.15
        assert info["history"][-1]["loss"] < info["history"][0]["loss"]


class TestMonitor:
    def test_straggler_detection(self):
        mon = StepMonitor(z_thresh=4.0)
        for i in range(20):
            assert not mon.record(i, 0.1 + 0.001 * (i % 3))
        assert mon.record(20, 1.0)  # 10x step time -> straggler
        assert mon.summary()["stragglers"] == 1

    def test_heartbeat(self, tmp_path):
        hb = HeartbeatFile(str(tmp_path / "hb.json"), every=0.0)
        hb.beat(5)
        assert HeartbeatFile.is_alive(str(tmp_path / "hb.json"))
        assert not HeartbeatFile.is_alive(str(tmp_path / "missing.json"))


class TestCompression:
    def test_int8_roundtrip_error_bounded(self):
        x = jax.random.normal(KEY, (1024,))
        payload, meta = compression.compress(x, "int8", KEY)
        rec = compression.decompress(payload, meta, "int8")
        assert float(jnp.abs(rec - x).max()) <= float(meta / 127.0) + 1e-6

    def test_stochastic_rounding_unbiased(self):
        x = jnp.full((20000,), 0.3)
        keys = jax.random.split(KEY, 8)
        recs = []
        for k in keys:
            p, m = compression.compress(x, "int8", k)
            recs.append(compression.decompress(p, m, "int8").mean())
        assert abs(float(jnp.stack(recs).mean()) - 0.3) < 1e-3

    def test_error_feedback_converges(self):
        # compressed grad descent with EF reaches the optimum anyway
        target = jnp.asarray(np.random.default_rng(1).normal(0, 1, (16,)))
        w = {"w": jnp.zeros(16)}
        res = compression.init_residual(w)
        for i in range(300):
            g = {"w": 2 * (w["w"] - target)}
            g, res = compression.apply_error_feedback(
                g, res, "int8", jax.random.fold_in(KEY, i))
            w = {"w": w["w"] - 0.05 * g["w"]}
        assert float(jnp.abs(w["w"] - target).max()) < 0.02

    def test_bf16_codec(self):
        x = jax.random.normal(KEY, (128,))
        p, m = compression.compress(x, "bf16")
        rec = compression.decompress(p, m, "bf16")
        assert float(jnp.abs(rec - x).max()) < 0.01


class TestElastic:
    def test_mesh_shapes(self):
        assert elastic.choose_mesh_shape(512)[0] == (2, 16, 16)
        assert elastic.choose_mesh_shape(256)[0] == (16, 16)
        shape, names = elastic.choose_mesh_shape(248)  # lost a host
        assert int(np.prod(shape)) == 248
        shape, names = elastic.choose_mesh_shape(4, model_axis=2)
        assert int(np.prod(shape)) == 4

    def test_degradation_sequence(self):
        seq = elastic.degraded_meshes(256, 3)
        sizes = [int(np.prod(s)) for s, _ in seq]
        assert sizes == [256, 248, 240, 232]

"""Property + behaviour tests for the Fractal partition engine."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.core import fractal as fr

jax.config.update("jax_platform_name", "cpu")


def make_cloud(seed, n, kind="clusters"):
    rng = np.random.default_rng(seed)
    if kind == "clusters":
        k = max(1, n // 300)
        centers = rng.uniform(-3, 3, (k, 3))
        pts = np.concatenate([
            rng.normal(c, rng.uniform(0.1, 0.5), (n // k, 3)) for c in centers
        ])
        pts = np.concatenate([pts, rng.uniform(-3, 3, (n - len(pts), 3))])
    elif kind == "uniform":
        pts = rng.uniform(-1, 1, (n, 3))
    elif kind == "plane":  # coplanar: the paper's degenerate-dim case
        pts = rng.uniform(-1, 1, (n, 3))
        pts[:, 2] = 0.25
    else:
        raise ValueError(kind)
    return jnp.asarray(pts.astype(np.float32))


def check_invariants(pts, part, th, strategy):
    n = pts.shape[0]
    perm = np.asarray(part.perm)
    assert sorted(perm.tolist()) == list(range(n)), "perm not a permutation"
    np.testing.assert_allclose(np.asarray(part.coords),
                               np.asarray(pts)[perm], rtol=0, atol=0)
    isl = np.asarray(part.is_leaf)
    real = np.where(isl)[0]
    ls = np.asarray(part.leaf_start)[real]
    lr = np.asarray(part.leaf_rsize)[real]
    lv = np.asarray(part.leaf_vsize)[real]
    # Leaves tile [0, n) contiguously in DFT order.
    ends = ls + lr
    assert ls[0] == 0 and ends[-1] == n and (ls[1:] == ends[:-1]).all()
    assert (lv <= lr).all()
    # Balanced unless flagged (uniform is allowed to be imbalanced: that is
    # the paper's criticism of space-uniform partitioning).
    if strategy != fr.UNIFORM:
        assert bool(part.overflowed) == bool((lv > th).any())
    # Parent range covers the leaf (search-space rule is well-formed).
    ps = np.asarray(part.parent_start)[real]
    pr = np.asarray(part.parent_rsize)[real]
    assert (ps <= ls).all() and (ps + pr >= ends).all()
    # Valid-prefix property: every leaf range is [valid... | invalid...].
    vp = np.asarray(part.valid)
    for s, v, r in zip(ls, lv, lr):
        assert vp[s:s + v].all()
        assert not vp[s + v:s + r].any()


@pytest.mark.parametrize("strategy", fr.STRATEGIES)
@pytest.mark.parametrize("kind", ["clusters", "uniform", "plane"])
def test_partition_invariants(strategy, kind):
    pts = make_cloud(0, 1024, kind)
    part = jax.jit(
        lambda p: core.partition(p, th=64, strategy=strategy))(pts)
    check_invariants(pts, part, 64, strategy)


def test_fractal_balances_clusters():
    pts = make_cloud(3, 2048, "clusters")
    part = jax.jit(lambda p: core.partition(p, th=128))(pts)
    assert not bool(part.overflowed)
    assert int(part.max_leaf_vsize) <= 128
    assert int(part.sort_passes) == 0  # sorter-free: the paper's key claim


def test_kdtree_uses_sorts_fractal_does_not():
    pts = make_cloud(4, 1024, "clusters")
    pf = jax.jit(lambda p: core.partition(p, th=64, strategy=fr.FRACTAL))(pts)
    pk = jax.jit(lambda p: core.partition(p, th=64, strategy=fr.KDTREE))(pts)
    assert int(pf.sort_passes) == 0
    assert int(pk.sort_passes) >= int(
        jnp.ceil(jnp.log2(1024 / 64)))  # one sort per level at least


def test_traversal_count_matches_paper_formula():
    # Paper: 1024 points -> 4 traversals; 289K -> 11 (th=256) for well-
    # spread clouds.  Uniform clouds hit the information-theoretic minimum.
    pts = make_cloud(5, 1024, "uniform")
    part = jax.jit(lambda p: core.partition(p, th=256))(pts)
    assert int(part.traversals) <= fr.default_depth(1024, 256)
    assert int(part.traversals) >= math.ceil(math.log2(1024 / 256))


def test_midpoint_rule_matches_alg1():
    # Level-0 split must be the x midpoint of (max+min)/2 (paper Alg.1 row 5)
    pts = make_cloud(6, 512, "uniform")
    part = jax.jit(lambda p: core.partition(p, th=256, depth=1))(pts)
    x = np.asarray(pts)[:, 0]
    mid = (x.max() + x.min()) / 2
    perm = np.asarray(part.perm)
    ls = np.asarray(part.leaf_start)
    lr = np.asarray(part.leaf_rsize)
    real = np.where(np.asarray(part.is_leaf))[0]
    assert len(real) == 2
    left = perm[ls[real[0]]:ls[real[0]] + lr[real[0]]]
    right = perm[ls[real[1]]:ls[real[1]] + lr[real[1]]]
    assert (x[left] <= mid).all() and (x[right] > mid).all()


def test_dims_cycle_xyz():
    # With depth 3 every axis is used once: blocks are separated on x then
    # y then z (Alg. 1 row 4).
    rng = np.random.default_rng(7)
    pts = jnp.asarray(rng.uniform(0, 1, (512, 3)).astype(np.float32))
    part = jax.jit(
        lambda p: core.partition(p, th=1000, depth=3,
                                 strategy=fr.UNIFORM))(pts)
    # 8 uniform cells == octants of the bbox.
    real = np.where(np.asarray(part.is_leaf))[0]
    assert len(real) == 8
    c = np.asarray(part.coords)
    ls, lr = np.asarray(part.leaf_start)[real], np.asarray(part.leaf_rsize)[real]
    mids = (np.asarray(pts).max(0) + np.asarray(pts).min(0)) / 2
    for b, (s, r) in enumerate(zip(ls, lr)):
        blk = c[s:s + r]
        if r == 0:
            continue
        for d in range(3):
            bit = (b >> (2 - d)) & 1
            if bit:
                assert (blk[:, d] > mids[d]).all()
            else:
                assert (blk[:, d] <= mids[d]).all()


def test_subtree_contiguity():
    """DFT property: the paper's 'adjacent memory blocks correspond to
    spatially adjacent regions' — any subtree is one contiguous range."""
    pts = make_cloud(8, 1024, "clusters")
    part = jax.jit(lambda p: core.partition(p, th=64))(pts)
    real = np.where(np.asarray(part.is_leaf))[0]
    slot = np.asarray(part.slot_of_leaf)[real]
    ls = np.asarray(part.leaf_start)[real]
    # DFT order: slots ascending <=> starts ascending.
    assert (np.diff(slot) > 0).all()
    assert (np.diff(ls) >= 0).all()


def test_duplicate_points_do_not_hang():
    # All-identical coordinates: extrema midpoint == point, nothing is ever
    # > mid, so the cloud cannot be split. Must terminate with overflow flag.
    pts = jnp.ones((256, 3), jnp.float32)
    part = jax.jit(lambda p: core.partition(p, th=32))(pts)
    assert bool(part.overflowed)
    check_invariants(pts, part, 32, fr.FRACTAL)


def test_overflow_surfaced_at_hard_cap_100k():
    """Depth-cap overflow is surfaced, not silent: 100k duplicate points
    cannot be split, so the hard cap leaves one >th leaf — partition warns
    with the offending (n, th) and check_overflow raises."""
    import warnings
    pts = jnp.ones((100_000, 3), jnp.float32)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        part = jax.jit(lambda p: core.partition(p, th=64))(pts)
        jax.block_until_ready(part.overflowed)
        jax.effects_barrier()
    msgs = [str(w.message) for w in caught
            if issubclass(w.category, fr.FractalOverflowWarning)]
    assert msgs and "n=100000" in msgs[0] and "th=64" in msgs[0], msgs
    assert bool(part.overflowed) and int(part.max_leaf_vsize) == 100_000
    with pytest.raises(fr.FractalOverflowError, match="100000.*th=64"):
        core.check_overflow(part, th=64)
    # non-overflowing partitions pass the strict check silently
    ok = jax.jit(lambda p: core.partition(p, th=64))(make_cloud(0, 1024))
    core.check_overflow(ok, th=64)
    # opt-out for timed loops: no callback, no warning
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        part = jax.jit(
            lambda p: core.partition(p, th=64, on_overflow="silent"))(pts)
        jax.block_until_ready(part.overflowed)
        jax.effects_barrier()
    assert not [w for w in caught
                if issubclass(w.category, fr.FractalOverflowWarning)]
    with pytest.raises(ValueError, match="on_overflow"):
        core.partition(pts, th=64, on_overflow="explode")


def test_dim0_phases_the_split_cycle():
    """dim0 offsets the split-dimension cycle (level l splits on
    (l + dim0) % 3) and accepts a traced scalar, so a vmapped plan can
    phase per cloud — the scene tiler's subtree-exactness hook (§10)."""
    pts = make_cloud(9, 512, "uniform")
    base = jax.jit(lambda p: core.partition(p, th=256, depth=1))(pts)
    ph1 = jax.jit(lambda p: core.partition(p, th=256, depth=1, dim0=1))(pts)
    x, y = np.asarray(pts)[:, 0], np.asarray(pts)[:, 1]
    for part, vals in ((base, x), (ph1, y)):     # dim0=1 -> level 0 on y
        mid = (vals.max() + vals.min()) / 2
        perm = np.asarray(part.perm)
        real = np.where(np.asarray(part.is_leaf))[0]
        ls = np.asarray(part.leaf_start)[real]
        lr = np.asarray(part.leaf_rsize)[real]
        left = perm[ls[0]:ls[0] + lr[0]]
        right = perm[ls[1]:ls[1] + lr[1]]
        assert (vals[left] <= mid).all() and (vals[right] > mid).all()
    # traced dim0 == static dim0, including under vmap
    traced = jax.jit(lambda p, d: core.partition(p, th=64, dim0=d))
    for d in range(3):
        st = core.partition(pts, th=64, dim0=d)
        tr = traced(pts, jnp.int32(d))
        np.testing.assert_array_equal(np.asarray(st.perm),
                                      np.asarray(tr.perm))
        check_invariants(pts, tr, 64, fr.FRACTAL)
    both = jax.vmap(lambda p, d: core.partition(p, th=64, dim0=d))(
        jnp.stack([pts, pts]), jnp.array([0, 2], jnp.int32))
    np.testing.assert_array_equal(
        np.asarray(both.perm[0]),
        np.asarray(core.partition(pts, th=64, dim0=0).perm))
    np.testing.assert_array_equal(
        np.asarray(both.perm[1]),
        np.asarray(core.partition(pts, th=64, dim0=2).perm))


def test_batched_vmap():
    rng = np.random.default_rng(11)
    pts = jnp.asarray(rng.normal(0, 1, (4, 512, 3)).astype(np.float32))
    parts = jax.vmap(lambda p: core.partition(p, th=64))(pts)
    assert parts.perm.shape == (4, 512)
    for b in range(4):
        part_b = jax.tree.map(lambda a: a[b], parts)
        check_invariants(pts[b], part_b, 64, fr.FRACTAL)

"""Tests for Block-Parallel Point Operations vs the global oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.core import ref

jax.config.update("jax_platform_name", "cpu")


def cloud(seed, n=1024):
    rng = np.random.default_rng(seed)
    k = 4
    pts = np.concatenate([
        rng.normal(rng.uniform(-2, 2, 3), rng.uniform(0.2, 0.5), (n // k, 3))
        for _ in range(k)
    ]).astype(np.float32)
    return jnp.asarray(pts[:n])


TH = 64


def pipeline(pts, rate=0.25, radius=0.25, num=16):
    n = pts.shape[0]

    @jax.jit
    def run(p):
        part = core.partition(p, th=TH)
        samp = core.blockwise_fps(part, rate=rate, k_out=int(n * rate),
                                  bs=TH)
        nb = core.blockwise_ball_query(part, samp, radius=radius, num=num,
                                       w=2 * TH)
        return part, samp, nb

    return run(pts)


class TestBlockwiseFPS:
    def test_samples_are_distinct_valid_points(self):
        pts = cloud(0)
        part, samp, _ = pipeline(pts)
        sidx = np.asarray(samp.idx)
        sval = np.asarray(samp.valid)
        assert len(np.unique(sidx[sval])) == sval.sum()
        assert np.asarray(part.valid)[sidx[sval]].all()

    def test_fixed_rate_quota(self):
        # Paper: one fixed rate across all blocks, no extra hyper-params.
        pts = cloud(1)
        part, samp, _ = pipeline(pts, rate=0.25)
        q = np.asarray(samp.quota)
        v = np.asarray(part.leaf_vsize)
        isl = np.asarray(part.is_leaf)
        np.testing.assert_array_equal(
            q[isl], np.minimum(np.round(0.25 * v[isl]), samp.local_idx.shape[1]))

    def test_per_block_counts_aggregate(self):
        pts = cloud(2)
        part, samp, _ = pipeline(pts)
        assert int(samp.total) == int(np.asarray(samp.quota).sum())
        assert int(samp.valid.sum()) == min(int(samp.total), samp.k_out)

    def test_coverage_beats_random_and_tracks_global(self):
        """FPS-ness proxy for the paper's <0.2% accuracy claim: block-wise
        sample coverage must be far closer to global FPS than to random."""
        pts = cloud(3, n=2048)
        pts_np = np.asarray(pts)
        part, samp, _ = pipeline(pts, rate=0.25)
        sel = np.asarray(part.coords)[np.asarray(samp.idx)[np.asarray(samp.valid)]]

        def mean_cov(s):
            d = ((pts_np[:, None, :] - s[None, :, :]) ** 2).sum(-1)
            return float(np.sqrt(d.min(1)).mean())

        gi, _ = ref.fps(pts, jnp.ones(len(pts_np), bool), len(sel))
        rng = np.random.default_rng(0)
        cov_g = mean_cov(pts_np[np.asarray(gi)])
        cov_b = mean_cov(sel)
        cov_r = mean_cov(pts_np[rng.choice(len(pts_np), len(sel), False)])
        assert cov_b < cov_r, "block-wise FPS no better than random"
        assert cov_b < 2.0 * cov_g, "block-wise FPS far off global FPS"

    def test_block_fps_matches_global_fps_within_one_block(self):
        # When the whole cloud fits one leaf the two algorithms coincide.
        rng = np.random.default_rng(4)
        pts = jnp.asarray(rng.normal(0, 1, (48, 3)).astype(np.float32))
        part = core.partition(pts, th=TH)
        samp = core.blockwise_fps(part, rate=0.25, k_out=12, bs=TH)
        gi, _ = ref.fps(part.coords, part.valid, 12)
        bi = np.asarray(samp.idx)[np.asarray(samp.valid)]
        np.testing.assert_array_equal(np.sort(bi), np.sort(np.asarray(gi)))


class TestBlockwiseBallQuery:
    def test_neighbors_are_in_radius(self):
        pts = cloud(5)
        part, samp, nb = pipeline(pts, radius=0.3)
        c = np.asarray(part.coords)
        ce = c[np.asarray(samp.idx)]
        ne = c[np.asarray(nb.idx)]
        d = ((ce[:, None, :] - ne) ** 2).sum(-1)
        m = np.asarray(nb.mask) & np.asarray(samp.valid)[:, None]
        assert (d[m] <= 0.3 ** 2 + 1e-5).all()

    def test_self_always_found(self):
        # Centers are sampled from the cloud: distance-0 self neighbor must
        # always be in the result set (it is in the leaf => in the window).
        pts = cloud(6)
        part, samp, nb = pipeline(pts, radius=0.2)
        sval = np.asarray(samp.valid)
        has_self = (np.asarray(nb.idx) == np.asarray(samp.idx)[:, None]).any(1)
        assert has_self[sval].all()

    def test_recall_vs_global(self):
        # Paper regime: query radius well below the block extent (S3DIS
        # radii are ~0.1 at scene scale with th=256). The residual recall
        # loss is the paper's accepted deviation, recovered by retraining.
        pts = cloud(7, n=2048)
        radius = 0.08
        part, samp, nb = pipeline(pts, radius=radius, num=16)
        sval = np.asarray(samp.valid)
        centers = np.asarray(part.coords)[np.asarray(samp.idx)[sval]]
        gi, gc = ref.ball_query(part.coords, part.valid,
                                jnp.asarray(centers),
                                jnp.ones(len(centers), bool), radius, 16)
        gi, gc = np.asarray(gi), np.asarray(gc)
        bi = np.asarray(nb.idx)[sval]
        bm = np.asarray(nb.mask)[sval]
        recalls = []
        for i in range(len(centers)):
            gset = set(gi[i][:min(gc[i], 16)].tolist())
            if gset:
                recalls.append(len(gset & set(bi[i][bm[i]].tolist())) / len(gset))
        assert np.mean(recalls) > 0.9, f"recall {np.mean(recalls)}"

    def test_exact_when_single_block(self):
        rng = np.random.default_rng(8)
        pts = jnp.asarray(rng.normal(0, 0.3, (56, 3)).astype(np.float32))
        part = core.partition(pts, th=TH)
        samp = core.blockwise_fps(part, rate=0.25, k_out=14, bs=TH)
        nb = core.blockwise_ball_query(part, samp, radius=0.25, num=8,
                                       w=2 * TH)
        sval = np.asarray(samp.valid)
        centers = part.coords[samp.idx]
        gi, gc = ref.ball_query(part.coords, part.valid, centers,
                                samp.valid, 0.25, 8)
        # same candidate set => identical neighbor sets
        for i in np.where(sval)[0]:
            bset = set(np.asarray(nb.idx)[i][np.asarray(nb.mask)[i]].tolist())
            gset = set(np.asarray(gi)[i][:min(int(np.asarray(gc)[i]), 8)].tolist())
            assert bset == gset


class TestBlockwiseInterpolate:
    def test_exact_when_single_block(self):
        rng = np.random.default_rng(9)
        pts = jnp.asarray(rng.normal(0, 0.3, (60, 3)).astype(np.float32))
        part = core.partition(pts, th=TH)
        samp = core.blockwise_fps(part, rate=0.25, k_out=15, bs=TH)
        feats = jnp.asarray(rng.normal(0, 1, (15, 4)).astype(np.float32))
        feats = feats * samp.valid[:, None]
        out, i3, w3 = core.blockwise_interpolate(part, samp, feats, wc=32,
                                                 bs=TH)
        nvalid = int(samp.valid.sum())
        gout, _, _ = ref.interpolate_3nn(
            part.coords, samp.coords[:nvalid],
            jnp.ones((nvalid,), bool), feats[:nvalid])
        np.testing.assert_allclose(np.asarray(out), np.asarray(gout),
                                   rtol=2e-4, atol=2e-5)

    def test_smooth_field_reconstruction(self):
        pts = cloud(10, n=2048)
        part, samp, _ = pipeline(pts)
        f = jnp.sin(part.coords @ jnp.array([[1.0], [2.0], [0.5]]))
        sfeats = f[samp.idx] * samp.valid[:, None]
        out, _, w3 = core.blockwise_interpolate(part, samp, sfeats, wc=64,
                                                bs=TH)
        vp = np.asarray(part.valid)
        err = np.abs(np.asarray(out) - np.asarray(f))[vp].mean()
        assert err < 0.12, err
        # weights are a convex combination
        w = np.asarray(w3)[vp]
        np.testing.assert_allclose(w.sum(-1), 1.0, atol=1e-4)


class TestGather:
    def test_gather_matches_ref(self):
        rng = np.random.default_rng(11)
        feats = jnp.asarray(rng.normal(0, 1, (256, 8)).astype(np.float32))
        idx = jnp.asarray(rng.integers(0, 256, (37, 5)))
        np.testing.assert_array_equal(np.asarray(core.gather(feats, idx)),
                                      np.asarray(ref.gather(feats, idx)))

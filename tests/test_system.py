"""End-to-end system behaviour tests (the paper's full pipeline)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro.core import ref
from repro.data import synthetic

jax.config.update("jax_platform_name", "cpu")


def test_full_fractalcloud_pipeline():
    """Partition -> BWS -> BWG -> BWI -> BWGa on one scene, checking every
    cross-op contract (the paper's Fig. 7 dataflow)."""
    rng = np.random.default_rng(0)
    n, th = 2048, 128
    pts = jnp.asarray(np.concatenate([
        rng.normal([0, 0, 0], 0.4, (900, 3)),
        rng.normal([2, 2, 0], 0.4, (900, 3)),
        rng.uniform(-1, 3, (248, 3))]).astype(np.float32))

    @jax.jit
    def pipeline(p):
        part = core.partition(p, th=th)
        samp = core.blockwise_fps(part, rate=0.25, k_out=n // 4, bs=th)
        nb = core.blockwise_ball_query(part, samp, radius=0.3, num=16,
                                       w=2 * th)
        feats = jnp.sin(part.coords @ jnp.ones((3, 8)))       # (n, 8)
        gathered = core.gather(feats, nb.idx)                 # BWGa
        pooled = jnp.max(jnp.where(nb.mask[..., None], gathered, -1e30),
                         axis=1)
        pooled = jnp.where(nb.mask.any(-1, keepdims=True), pooled, 0.0)
        out, _, _ = core.blockwise_interpolate(part, samp, pooled,
                                               wc=64, bs=th)
        return part, samp, nb, out

    part, samp, nb, out = pipeline(pts)
    assert not bool(part.overflowed)
    assert int(samp.valid.sum()) > 0.9 * (n // 4)
    assert bool(jnp.isfinite(out).all())
    # every valid point got an interpolated value
    vp = np.asarray(part.valid)
    assert (np.abs(np.asarray(out))[vp].sum(-1) > 0).mean() > 0.99


def test_pipeline_is_permutation_invariant():
    """Shuffling the input cloud must not change the partition *structure*
    (leaf point-sets are a function of geometry alone); the FPS sample set
    may shift (the in-block start point is layout-dependent, like the
    paper's random FPS seed) but stays substantially overlapping."""
    rng = np.random.default_rng(1)
    pts = rng.normal(0, 1, (512, 3)).astype(np.float32)
    perm = rng.permutation(512)

    def run(p):
        part = core.partition(jnp.asarray(p), th=64)
        samp = core.blockwise_fps(part, rate=0.25, k_out=128, bs=64)
        real = np.where(np.asarray(part.is_leaf))[0]
        c = np.asarray(part.coords)
        ls = np.asarray(part.leaf_start)[real]
        lr_ = np.asarray(part.leaf_rsize)[real]
        leaf_sets = {frozenset(map(tuple, np.round(c[s:s + r], 5).tolist()))
                     for s, r in zip(ls, lr_)}
        sel = np.asarray(samp.coords)[np.asarray(samp.valid)]
        return leaf_sets, set(map(tuple, np.round(sel, 5).tolist()))

    leaves_a, samp_a = run(pts)
    leaves_b, samp_b = run(pts[perm])
    assert leaves_a == leaves_b, "partition structure not perm-invariant"
    inter = len(samp_a & samp_b) / max(len(samp_a | samp_b), 1)
    assert inter > 0.3, inter


def test_end_to_end_determinism():
    pts, _ = synthetic.classification_batch(0, 0, 1, 512)

    @jax.jit
    def run(p):
        part = core.partition(p, th=64)
        samp = core.blockwise_fps(part, rate=0.25, k_out=128, bs=64)
        return samp.idx

    a = run(pts[0])
    b = run(pts[0])
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_scaling_complexity_trend():
    """Block ops scale ~linearly in n while global FPS is O(n^2): the cost
    ratio must widen with n (paper Fig. 4's bottleneck-shift claim),
    measured structurally via op-count models rather than wall-time."""
    def global_ops(n, k):
        return n * k                       # distance updates

    def block_ops(n, th, rate):
        nb = max(1, 2 * n // th)
        return nb * th * int(rate * th)    # per-block FPS

    r1 = global_ops(1024, 256) / block_ops(1024, 64, 0.25)
    r2 = global_ops(65536, 16384) / block_ops(65536, 64, 0.25)
    assert r2 > r1 * 10

"""Distribution tests: logical rules, spec trees, and a real multi-device
jit on host devices (subprocess: device count must be set pre-import)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist import logical

jax.config.update("jax_platform_name", "cpu")


class TestLogicalRules:
    def test_spec_mapping(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        with logical.logical_rules(mesh, logical.RULES_V0):
            assert logical.spec(("batch", None, "ff")) == \
                P(("data",), None, "model")
            assert logical.spec((None, None)) == P(None, None)
        # outside a context: no-op
        assert logical.spec(("batch",)) == P()

    def test_missing_mesh_axis_dropped(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))  # no "pod"
        with logical.logical_rules(mesh, logical.RULES_V0):
            # "batch" -> ("pod","data") but pod is absent
            assert logical.spec(("batch",)) == P(("data",),)

    def test_param_specs_tree(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        axes = {"w": ("embed_fsdp", "ff"), "g": None,
                "nested": {"e": ("experts", None, "ff")}}
        specs = logical.param_specs(axes, mesh)
        assert specs["w"].spec == P("data", "model")
        assert specs["g"].spec == P()
        assert specs["nested"]["e"].spec == P("data", None, "model")

    def test_lc_noop_without_context(self):
        x = jax.numpy.ones((4, 4))
        assert logical.lc(x, "batch", "ff") is x


SUBPROCESS_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import configs
    from repro.dist import logical
    from repro.lm import steps as steps_lib, model as M
    from repro.train import optimizer as opt_lib

    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cfg = configs.lm_reduced("gemma2-2b")
    params, axes = M.init(jax.random.PRNGKey(0), cfg)
    p_sh = logical.param_specs(axes, mesh, logical.RULES_V0)
    params = jax.device_put(params, p_sh)
    opt = opt_lib.init(params)
    step = steps_lib.make_train_step(
        cfg, opt_lib.OptConfig(lr=1e-3, warmup=0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    b_sh = NamedSharding(mesh, P(("data",), None))
    batch = jax.device_put(batch, {"tokens": b_sh, "labels": b_sh})
    with logical.logical_rules(mesh, logical.RULES_V0):
        jitted = jax.jit(step)
        p1, o1, m1 = jitted(params, opt, batch)
        p2, o2, m2 = jitted(p1, o1, batch)
    print(json.dumps({
        "loss1": float(m1["loss"]), "loss2": float(m2["loss"]),
        "n_dev": len(jax.devices()),
        "sharded": any(len(x.sharding.device_set) > 1
                       for x in jax.tree.leaves(p1)),
    }))
""")


def test_multidevice_train_step_runs():
    """End-to-end SPMD: 8 host devices, (4,2) mesh, real sharded train
    step with the v0 logical rules — loss finite and decreasing."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", SUBPROCESS_PROG],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    data = json.loads(out.stdout.strip().splitlines()[-1])
    assert data["n_dev"] == 8
    assert data["sharded"], "no parameter was actually sharded"
    assert np.isfinite(data["loss1"])
    assert data["loss2"] < data["loss1"]


MOE_A2A_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.dist import logical
    from repro.lm import moe as moe_lib

    mesh = jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    key = jax.random.PRNGKey(0)
    p, _ = moe_lib.moe_init(key, 32, 48, 8, kind="swiglu")
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 32))
    with logical.logical_rules(mesh, logical.RULES_V0):
        # cf high enough that neither global nor per-group capacity drops
        f_g = jax.jit(lambda p, x: moe_lib.moe_apply(
            p, x, n_experts=8, top_k=2, capacity_factor=8.0,
            dispatch="global_sort")[0])
        f_a = jax.jit(lambda p, x: moe_lib.moe_apply(
            p, x, n_experts=8, top_k=2, capacity_factor=8.0,
            dispatch="grouped_a2a")[0])
        yg = f_g(p, x)
        ya = f_a(p, x)
    err = float(jnp.max(jnp.abs(yg - ya)))
    print(json.dumps({"err": err,
                      "scale": float(jnp.max(jnp.abs(yg)))}))
""")


def test_grouped_a2a_moe_matches_global_sort():
    """§Perf variant correctness: grouped all-to-all dispatch == global
    sort dispatch when nothing is dropped, on a real 8-device mesh."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", MOE_A2A_PROG],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    data = json.loads(out.stdout.strip().splitlines()[-1])
    assert data["err"] < 1e-4 * max(data["scale"], 1.0), data


def test_mesh_functions_pure():
    """Importing launch.mesh must not initialize jax device state."""
    import importlib
    import repro.launch.mesh as mesh_mod
    importlib.reload(mesh_mod)  # would fail if module-level jax state

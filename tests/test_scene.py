"""repro.scene: tile -> halo -> stitch (docs/DESIGN.md §10).

Covers the tiler contract (tiles = disjoint coarse-leaf covers, halo ring
within radius of the tile bbox), the owner-tile stitching rule (halo rows
are never observed), the chunked scene generator (counter-based RNG:
chunk-size invariant), and the §10 exactness oracle: with halo=0 and the
single-SA-stage model, stitched tile-wise seg logits equal a direct
whole-scene forward (same th/strategy/impl) — tiles are exact subtrees of
the global fractal tree, re-derived per tile via the dim0 split-phase.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import core, scene
from repro.data import synthetic
from repro.models import pnn

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# Scene generator (chunked, counter-based RNG).
# ---------------------------------------------------------------------------

def test_scene_generator_chunk_invariant():
    """Per-point fold_in keys: the stream must not depend on chunking."""
    p1, l1 = synthetic.scene(0, 3000, chunk=256)
    p2, l2 = synthetic.scene(0, 3000, chunk=3000)
    np.testing.assert_array_equal(p1, p2)
    np.testing.assert_array_equal(l1, l2)
    assert p1.shape == (3000, 3) and p1.dtype == np.float32
    assert l1.shape == (3000,) and l1.dtype == np.int32
    assert set(np.unique(l1)) <= set(range(synthetic.NUM_SHAPES))
    p3, _ = synthetic.scene(1, 3000)
    assert not np.array_equal(p1, p3)
    with pytest.raises(ValueError):
        synthetic.scene(0, 0)


# ---------------------------------------------------------------------------
# Tiler: coverage, halo ring.
# ---------------------------------------------------------------------------

def test_tile_scene_covers_disjointly():
    pts, _ = synthetic.scene(0, 4096, objects=8)
    plan = scene.tile_scene(pts, tile_points=512)
    assert plan.num_tiles >= 2
    owned = np.concatenate([t.owned for t in plan.tiles])
    assert sorted(owned.tolist()) == list(range(4096))   # exact cover
    for t in plan.tiles:
        assert 0 < t.n_owned <= 512
        assert t.dim0 == t.depth % 3
        tpts = pts[t.owned]
        np.testing.assert_allclose(tpts.min(0), t.lo)
        np.testing.assert_allclose(tpts.max(0), t.hi)
    assert (scene.owner_of(plan) >= 0).all()


def test_halo_ring_contract():
    pts, _ = synthetic.scene(0, 4096, objects=8)
    halo_r = 0.4
    plan = scene.tile_scene(pts, tile_points=512, halo=halo_r,
                            max_halo_points=64)
    assert plan.halo_points > 0
    for t in plan.tiles:
        assert len(t.halo) <= 64
        assert not set(t.halo.tolist()) & set(t.owned.tolist())
        if len(t.halo):
            d = np.maximum(np.maximum(t.lo - pts[t.halo],
                                      pts[t.halo] - t.hi), 0.0)
            assert (np.sqrt((d * d).sum(-1)) <= halo_r + 1e-6).all()
        # tile cloud layout: owned prefix, halo tail
        assert t.indices.shape == (t.n,)
        np.testing.assert_array_equal(t.indices[:t.n_owned], t.owned)
    # halo off -> no context points anywhere
    plan0 = scene.tile_scene(pts, tile_points=512, halo=0.0)
    assert plan0.halo_points == 0


def test_stitch_owner_tile_priority():
    """Halo rows carry sentinels; stitched output must never contain one —
    the owner-tile rule resolves every halo-overlap point."""
    pts, _ = synthetic.scene(0, 2048, objects=4)
    plan = scene.tile_scene(pts, tile_points=256, halo=0.5,
                            max_halo_points=64)
    assert plan.halo_points > 0
    outputs = {}
    for t in plan.tiles:
        rows = np.full((t.n, 3), float(t.tid), np.float32)
        rows[t.n_owned:] = np.nan                       # halo sentinel
        outputs[t.tid] = rows
    out = scene.stitch(plan, outputs, 3)
    assert np.isfinite(out).all()                       # no halo row leaked
    np.testing.assert_array_equal(out[:, 0],
                                  scene.owner_of(plan).astype(np.float32))
    # row-count mismatches are loud, not silent
    outputs[plan.tiles[0].tid] = outputs[plan.tiles[0].tid][:-1]
    with pytest.raises(ValueError, match="rows"):
        scene.stitch(plan, outputs, 3)


def test_scene_engine_rejects_tiny_tiles():
    with pytest.raises(ValueError, match="tile_points"):
        scene.SceneEngine(scene.SceneConfig(tile_points=64, th=256))


def test_scene_engine_fails_fast_on_overflowed_tiling():
    """An unsplittable (all-duplicate) region deeper than the depth cap
    must raise the actionable overflow error before any tile is
    submitted — not an opaque bucket-ladder error mid-stream."""
    pts = np.zeros((2048, 3), np.float32)
    eng = scene.SceneEngine(scene.SceneConfig(tile_points=512, th=64,
                                              impl="xla", halo=0.0))
    with pytest.raises(core.FractalOverflowError, match="tile_points=512"):
        eng.infer(pts)


def test_scene_surfaces_tile_internal_overflow():
    """An unsplittable cluster bigger than th but smaller than
    tile_points passes the coarse-plan check, so it must surface from
    the serve plan executable instead (ServeConfig.on_overflow) — never
    silent truncation."""
    import warnings
    pts, _ = synthetic.scene(0, 2048, objects=4)
    pts[300:500] = pts[300]                     # 200 duplicates, th=64
    cfg = scene.SceneConfig(
        tile_points=512, halo=0.0, th=64, impl="xla", microbatch=2,
        stages=(pnn.SAStage(0.25, 0.25, 8, (8, 8)),), fp_widths=((8,),))
    eng = scene.SceneEngine(cfg)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        logits, plan = eng.infer(pts)
        jax.effects_barrier()
    assert not plan.overflowed                  # coarse tiling is fine
    assert logits.shape == (2048, cfg.num_classes)
    assert [w for w in caught
            if issubclass(w.category, core.FractalOverflowWarning)]


# ---------------------------------------------------------------------------
# §10 exactness oracle: stitched tiles == whole-scene forward.
# ---------------------------------------------------------------------------

def _check_exactness_preconditions(pts, *, n, tile_points, th, rate):
    """The two static-budget conditions under which tiling is exact (§10):
    the whole-scene run must not truncate sample quotas (k_out), and every
    model leaf must sit >= 2 levels below its tile node (so parent search
    windows stay inside the tile).  Seeds in the tests are chosen to
    satisfy both; assert so drift fails loudly."""
    part_g = jax.jit(lambda p: core.partition(p, th=th))(pts)
    k_out = int(round(rate * n))
    samp = core.blockwise_fps(part_g, rate=rate, k_out=k_out, bs=th,
                              impl="xla")
    assert int(samp.total) <= k_out, (int(samp.total), k_out)
    part_c = jax.jit(lambda p: core.partition(p, th=tile_points))(pts)
    isl_c = np.asarray(part_c.is_leaf)
    sc = np.asarray(part_c.leaf_start)
    rc = np.asarray(part_c.leaf_rsize)
    vc = np.asarray(part_c.leaf_vsize)
    dc = np.asarray(part_c.leaf_depth)
    isl_g = np.asarray(part_g.is_leaf)
    sg = np.asarray(part_g.leaf_start)[isl_g]
    dg = np.asarray(part_g.leaf_depth)[isl_g]
    for i in np.nonzero(isl_c)[0]:
        if vc[i] == 0:
            continue
        inside = (sg >= sc[i]) & (sg < sc[i] + rc[i])
        assert dg[inside].min() >= dc[i] + 2, f"tile at depth {dc[i]}"


@pytest.mark.parametrize("impl,seed,n,tile_points", [
    ("xla", 3, 4096, 1024),
    ("pallas", 8, 2048, 512),      # interpret mode off-TPU
])
def test_scene_matches_whole_forward(impl, seed, n, tile_points):
    """Acceptance oracle: halo=0 + single-SA-stage model + per-tile dim0
    -> stitched tile-wise logits match the direct whole-scene forward
    (same th/strategy/impl) to 1e-4 on owned points (all points: with
    halo=0 every tile row is owned)."""
    th = 64
    cfg = pnn.scene_seg(n=n, th=th, impl=impl, widths=(16, 16), fp=(16, 16))
    pts_np, _ = synthetic.scene(seed, n, objects=n // 512)
    pts = jnp.asarray(pts_np)
    _check_exactness_preconditions(pts, n=n, tile_points=tile_points, th=th,
                                   rate=cfg.stages[0].rate)

    params = pnn.init(jax.random.PRNGKey(0), cfg)
    direct = np.asarray(jax.jit(lambda c: pnn.apply(params, cfg, c))(pts))

    scfg = scene.SceneConfig(tile_points=tile_points, halo=0.0, th=th,
                             impl=impl, microbatch=2, stages=cfg.stages,
                             fp_widths=cfg.fp_widths)
    eng = scene.SceneEngine(scfg, params=params)
    out, plan = eng.infer(pts_np)
    assert plan.num_tiles >= 4
    np.testing.assert_allclose(out, direct, atol=1e-4, rtol=1e-4)
    # every tile hit one of the two bucket executables, each traced once
    traces = eng.engine.plans.traces
    assert all(v == 1 for v in traces.values()), dict(traces)


def test_scene_engine_multistage_halo_smoke():
    """The general path (2-stage model, halo on): approximate at borders
    by design, but structurally sound — finite logits, full coverage,
    bounded tile clouds, streamed results drained."""
    n = 2048
    pts, _ = synthetic.scene(0, n, objects=4)
    cfg = scene.SceneConfig(
        tile_points=512, halo=0.3, max_halo_points=128, th=64,
        impl="xla", microbatch=2,
        stages=(pnn.SAStage(0.25, 0.25, 8, (8, 8)),
                pnn.SAStage(0.25, 0.5, 8, (8, 16))),
        fp_widths=((16,), (8,)))
    eng = scene.SceneEngine(cfg)
    logits, plan = eng.infer(pts)
    assert logits.shape == (n, cfg.num_classes)
    assert np.isfinite(logits).all()
    assert plan.halo_points > 0
    assert plan.max_tile_n <= cfg.max_tile_cloud()
    assert not eng.engine.results            # all results drained
    st = eng.stats()
    assert st["served"] == plan.num_tiles

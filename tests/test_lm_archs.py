"""Per-arch smoke tests (reduced configs): forward/train step on CPU,
shape checks, no NaNs, and exact prefill+decode vs full-forward consistency
(validates KV caches, Mamba2 chunked==recurrent, mLSTM chunked==recurrent).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.lm import model as M
from repro.lm import steps as steps_lib
from repro.train import optimizer as opt_lib

jax.config.update("jax_platform_name", "cpu")

ARCHS = sorted(configs.ARCHS)
KEY = jax.random.PRNGKey(0)


def make_batch(cfg, b=2, s=32):
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    batch = {"labels": toks}
    if cfg.encoder_layers:
        batch["frames"] = jax.random.normal(KEY, (b, s, cfg.d_model)) * 0.1
        batch["dec_tokens"] = toks
    elif cfg.frontend == "embeddings":
        batch["frames"] = jax.random.normal(KEY, (b, s, cfg.d_model)) * 0.1
    else:
        batch["tokens"] = toks
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = configs.lm_reduced(arch)
    params, axes = M.init(KEY, cfg)
    batch = make_batch(cfg)
    loss, (ce, aux) = steps_lib.loss_fn(params, cfg, batch)
    assert jnp.isfinite(loss), arch
    assert float(ce) > 0
    # loss near ln(vocab) at init (uniform predictions)
    assert abs(float(ce) - np.log(cfg.vocab)) < 1.5, float(ce)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_updates_and_finite(arch):
    cfg = configs.lm_reduced(arch)
    params, _ = M.init(KEY, cfg)
    opt_cfg = opt_lib.OptConfig(lr=1e-3, warmup=0, total_steps=10)
    step = jax.jit(steps_lib.make_train_step(cfg, opt_cfg))
    opt_state = opt_lib.init(params)
    batch = make_batch(cfg)
    p1, o1, m1 = step(params, opt_state, batch)
    p2, o2, m2 = step(p1, o1, batch)
    assert jnp.isfinite(m1["loss"]) and jnp.isfinite(m2["loss"])
    assert float(m2["loss"]) < float(m1["loss"]), \
        f"{arch}: same-batch loss did not drop"
    # params actually moved
    delta = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(p1),
                                jax.tree.leaves(params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_full_forward(arch):
    cfg = configs.lm_reduced(arch)
    params, _ = M.init(KEY, cfg)
    b, s = 2, 16
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    if cfg.encoder_layers:
        frames = jax.random.normal(KEY, (b, s, cfg.d_model)) * 0.1
        h, _ = M.forward(params, cfg, frames=frames, dec_tokens=toks)
        _, cache = M.prefill(params, cfg, frames=frames,
                             dec_tokens=toks[:, :s - 1], max_len=s)
    else:
        h, _ = M.forward(params, cfg, tokens=toks)
        _, cache = M.prefill(params, cfg, tokens=toks[:, :s - 1], max_len=s)
    full = M.logits_for(params, cfg, h[:, -1:, :])
    dec, _ = M.decode_step(params, cfg, toks[:, s - 1:s], cache,
                           jnp.int32(s - 1))
    np.testing.assert_allclose(
        np.asarray(dec[:, 0, :cfg.vocab], np.float32),
        np.asarray(full[:, 0, :cfg.vocab], np.float32),
        rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["smollm-135m", "zamba2-7b", "xlstm-1.3b"])
def test_multi_step_decode(arch):
    """Greedy decode 4 tokens via cache == recomputing full forward.

    Each token is consumed exactly once (prefill eats toks[:s0]; decode
    eats one new token per step) — recurrent-state archs are sensitive to
    double-feeding, unlike idempotent KV caches."""
    cfg = configs.lm_reduced(arch)
    params, _ = M.init(KEY, cfg)
    b, s0, n_new = 1, 8, 4
    toks = jax.random.randint(KEY, (b, s0), 0, cfg.vocab)
    last, cache = M.prefill(params, cfg, tokens=toks, max_len=s0 + n_new)
    cur = toks
    for i in range(n_new):
        h, _ = M.forward(params, cfg, tokens=cur)
        nxt_full = jnp.argmax(
            M.logits_for(params, cfg, h[:, -1:, :]), -1)
        nxt_dec = jnp.argmax(last, -1)
        np.testing.assert_array_equal(np.asarray(nxt_full),
                                      np.asarray(nxt_dec))
        last, cache = M.decode_step(params, cfg, nxt_dec, cache,
                                    jnp.int32(cur.shape[1]))
        cur = jnp.concatenate([cur, nxt_dec], axis=1)


def test_chunked_loss_matches_unchunked():
    cfg = configs.lm_reduced("smollm-135m", loss_chunk=8)
    cfg_full = dataclasses.replace(cfg, loss_chunk=32)
    params, _ = M.init(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 32), 0, cfg.vocab)
    h, _ = M.forward(params, cfg, tokens=toks)
    l1 = M.lm_loss(params, cfg, h, toks)
    l2 = M.lm_loss(params, cfg_full, h, toks)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_vocab_padding_masked():
    cfg = configs.lm_reduced("smollm-135m", vocab=500)  # pads to 512
    assert cfg.padded_vocab == 512
    params, _ = M.init(KEY, cfg)
    h, _ = M.forward(params, cfg,
                     tokens=jax.random.randint(KEY, (1, 8), 0, 500))
    logits = M.logits_for(params, cfg, h[:, -1:, :])
    assert float(jnp.max(logits[..., 500:])) < -1e29


def test_loss_mask():
    cfg = configs.lm_reduced("smollm-135m")
    params, _ = M.init(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 32), 0, cfg.vocab)
    h, _ = M.forward(params, cfg, tokens=toks)
    full = M.lm_loss(params, cfg, h, toks)
    half_mask = jnp.arange(32)[None, :] < 16
    half = M.lm_loss(params, cfg, h, toks,
                     jnp.broadcast_to(half_mask, (2, 32)))
    assert not np.isclose(float(full), float(half))


class TestMoE:
    def test_no_drop_keeps_everything(self):
        from repro.lm import moe as moe_lib
        cfg = configs.lm_reduced("granite-moe-3b-a800m")
        p, _ = moe_lib.moe_init(KEY, 64, 64, 8, kind="swiglu")
        x = jax.random.normal(KEY, (2, 16, 64))
        _, aux = moe_lib.moe_apply(p, x, n_experts=8, top_k=2,
                                   no_drop=True)
        assert float(aux["frac_dropped"]) == 0.0

    def test_capacity_drops_under_pressure(self):
        from repro.lm import moe as moe_lib
        p, _ = moe_lib.moe_init(KEY, 64, 64, 8, kind="swiglu")
        x = jnp.broadcast_to(jax.random.normal(KEY, (1, 1, 64)),
                             (2, 32, 64))  # identical tokens route together
        y, aux = moe_lib.moe_apply(p, x, n_experts=8, top_k=2,
                                   capacity_factor=0.5)
        assert float(aux["frac_dropped"]) > 0.0
        assert jnp.isfinite(y).all()

    def test_aux_losses_finite_positive(self):
        from repro.lm import moe as moe_lib
        p, _ = moe_lib.moe_init(KEY, 32, 32, 4, kind="swiglu")
        x = jax.random.normal(KEY, (2, 8, 32))
        _, aux = moe_lib.moe_apply(p, x, n_experts=4, top_k=1)
        assert float(aux["aux_lb"]) >= 1.0 - 1e-3  # >= 1 by Cauchy-Schwarz
        assert float(aux["aux_z"]) >= 0.0


def test_scan_vs_unrolled_stack_identical():
    """The dry-run metric compiles (unrolled) must compute the same
    function as the scanned stack."""
    cfg = configs.lm_reduced("gemma3-12b")
    cfg_u = dataclasses.replace(cfg, scan_layers=False)
    params, _ = M.init(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    h1, _ = M.forward(params, cfg, tokens=toks)
    h2, _ = M.forward(params, cfg_u, tokens=toks)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=1e-5, atol=1e-5)


def test_xlstm_unroll_flag_identical():
    cfg = configs.lm_reduced("xlstm-1.3b")
    cfg_u = dataclasses.replace(
        cfg, xlstm=dataclasses.replace(cfg.xlstm, unroll=True))
    params, _ = M.init(KEY, cfg)
    toks = jax.random.randint(KEY, (1, 24), 0, cfg.vocab)
    h1, _ = M.forward(params, cfg, tokens=toks)
    h2, _ = M.forward(params, cfg_u, tokens=toks)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=1e-5, atol=1e-5)

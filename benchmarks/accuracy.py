"""Paper Fig. 14: network accuracy, original point ops vs FractalCloud BPPO.

Trains the same PNN classifier on synthetic shapes with (a) global point
ops and (b) block-parallel ops, then compares held-out accuracy — the
paper's <0.7% criterion, on the offline-container stand-in task."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import synthetic
from repro.models import pnn
from repro.train import optimizer as opt_lib
from benchmarks.common import emit


def _train(cfg, steps, batch=16, lr=2e-3, seed=0):
    params = pnn.init(jax.random.PRNGKey(seed), cfg)
    opt_cfg = opt_lib.OptConfig(lr=lr, warmup=10, total_steps=steps,
                                weight_decay=0.0)
    opt = opt_lib.init(params)

    @jax.jit
    def step(params, opt, pts, labels):
        def loss_f(p):
            logits = jax.vmap(lambda c: pnn.apply(p, cfg, c))(pts)
            ll = jax.nn.log_softmax(logits)
            return -jnp.mean(
                jnp.take_along_axis(ll, labels[:, None], 1))

        loss, grads = jax.value_and_grad(loss_f)(params)
        params, opt, _ = opt_lib.update(opt_cfg, grads, opt, params)
        return params, opt, loss

    for s in range(steps):
        pts, labels = synthetic.classification_batch(seed, s, batch,
                                                     cfg.n_points)
        params, opt, loss = step(params, opt, pts, labels)

    @jax.jit
    def evaluate(params, pts, labels):
        logits = jax.vmap(lambda c: pnn.apply(params, cfg, c))(pts)
        return jnp.mean(jnp.argmax(logits, -1) == labels)

    accs = []
    for s in range(8):
        pts, labels = synthetic.classification_batch(seed + 999, s, batch,
                                                     cfg.n_points)
        accs.append(float(evaluate(params, pts, labels)))
    return float(np.mean(accs)), float(loss)


def run(quick: bool = True):
    n = 256 if quick else 1024
    steps = 60 if quick else 400
    th = 32 if quick else 64
    for mode in ("global", "bppo"):
        cfg = pnn.pointnet2_cls(n=n, point_ops=mode, th=th)
        t0 = time.time()
        acc, loss = _train(cfg, steps)
        emit(f"accuracy/pointnet2_cls/{mode}", (time.time() - t0) * 1e6,
             f"acc={acc:.3f};final_loss={loss:.3f};steps={steps}")

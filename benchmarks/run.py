"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus a summary).  ``--full`` runs
paper-scale sizes (289K points, 400-step accuracy training); the default
quick mode keeps CI fast.

  partitioning   -> paper Figs. 5/16 (sorter vs traverser, 133x claim)
  point_ops      -> paper Figs. 4/13/15/18 (global vs BPPO, traffic model)
  threshold      -> paper Fig. 17 (th trade-off)
  accuracy       -> paper Fig. 14 (network accuracy, global vs BPPO)
  kernels        -> paper §VI-C RSPU ablation (reuse model + verification)
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: partitioning,point_ops,threshold,"
                         "accuracy,kernels")
    args = ap.parse_args(argv)
    quick = not args.full

    from benchmarks import (accuracy, kernels_bench, partitioning,
                            point_ops, threshold)
    suites = {
        "partitioning": partitioning.run,
        "point_ops": point_ops.run,
        "threshold": threshold.run,
        "accuracy": accuracy.run,
        "kernels": kernels_bench.run,
    }
    chosen = (args.only.split(",") if args.only else list(suites))
    print("name,us_per_call,derived")
    t0 = time.time()
    for name in chosen:
        suites[name](quick=quick)
    print(f"# total {time.time() - t0:.1f}s, quick={quick}",
          file=sys.stderr)


if __name__ == "__main__":
    main()

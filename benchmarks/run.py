"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus a summary).  ``--full`` runs
paper-scale sizes (289K points, 400-step accuracy training); the default
quick mode keeps CI fast.  ``--impl xla|pallas`` selects the point-op
execute backend for the suites that dispatch kernels; ``--json DIR`` writes
one machine-readable ``BENCH_<suite>.json`` per suite so the perf
trajectory is tracked across PRs.

  partitioning   -> paper Figs. 5/16 (sorter vs traverser, 133x claim)
  point_ops      -> paper Figs. 4/13/15/18 (global vs BPPO, traffic model)
  threshold      -> paper Fig. 17 (th trade-off)
  accuracy       -> paper Fig. 14 (network accuracy, global vs BPPO)
  kernels        -> paper §VI-C RSPU ablation (reuse model + verification)
  serve          -> deployment path: bucketed serving latency/throughput
                    (docs/DESIGN.md §9; both impls unless --impl is given)
  train          -> fine-tune step time, fwd vs fwd+bwd through the
                    execute-phase VJPs (docs/DESIGN.md §4; both impls
                    unless --impl is given)
  scene          -> scene-scale streaming inference: points/s + peak-RSS
                    scaling over 16k-262k-point scenes (docs/DESIGN.md
                    §10; both impls unless --impl is given)

See benchmarks/README.md for the BENCH_<suite>.json schema.
"""
from __future__ import annotations

import argparse
import inspect
import json
import os
import subprocess
import sys
import time


def _git_sha() -> str:
    """The repo HEAD, so every BENCH_<suite>.json pins the code it
    measured (perf trajectories are diffed across PRs)."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, cwd=os.path.dirname(os.path.abspath(__file__)))
        return out.stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def _write_suite_json(out_dir: str, suite: str, rows, meta: dict) -> str:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{suite}.json")
    payload = dict(meta, suite=suite, git_sha=_git_sha(), rows=[
        {"name": n, "us_per_call": us, "derived": d} for n, us, d in rows])
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: partitioning,point_ops,threshold,"
                         "accuracy,kernels,serve,scene,train")
    ap.add_argument("--impl", default=None, choices=["xla", "pallas"],
                    help="point-op execute backend for kernel-dispatching "
                         "suites (default: $REPRO_POINT_IMPL or xla)")
    ap.add_argument("--json", default=None, metavar="DIR",
                    help="write one BENCH_<suite>.json per suite into DIR")
    args = ap.parse_args(argv)
    quick = not args.full

    from benchmarks import (accuracy, common, kernels_bench, partitioning,
                            point_ops, scene_bench, serve_bench, threshold,
                            train_bench)
    suites = {
        "partitioning": partitioning.run,
        "point_ops": point_ops.run,
        "threshold": threshold.run,
        "accuracy": accuracy.run,
        "kernels": kernels_bench.run,
        "serve": serve_bench.run,
        "scene": scene_bench.run,
        "train": train_bench.run,
    }
    chosen = (args.only.split(",") if args.only else list(suites))
    print("name,us_per_call,derived")
    t0 = time.time()
    for name in chosen:
        fn = suites[name]
        kwargs = {"quick": quick}
        if args.impl and "impl" in inspect.signature(fn).parameters:
            kwargs["impl"] = args.impl
        row_start = len(common.ROWS)
        t_suite = time.time()
        ret = fn(**kwargs)
        if args.json:
            meta = {"quick": quick,
                    "elapsed_s": round(time.time() - t_suite, 3),
                    "unix_time": int(t_suite)}
            if isinstance(ret, str):
                # kernel-dispatching suites return the backend that ran
                # (--impl / $REPRO_POINT_IMPL resolved); others omit it.
                meta["impl"] = ret
            path = _write_suite_json(args.json, name,
                                     common.ROWS[row_start:], meta)
            print(f"# wrote {path}", file=sys.stderr)
    print(f"# total {time.time() - t0:.1f}s, quick={quick}",
          file=sys.stderr)


if __name__ == "__main__":
    main()

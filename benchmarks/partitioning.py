"""Paper Figs. 5/16 (dots): partitioning cost by strategy and scale.

Reports wall-time (CPU; relative ratios are what transfers), the number of
linear traversals (Fractal's cost unit) and the number of O(n log n) sorts
(the KD-tree's 'exclusive sorter' cost the paper eliminates: 11 traversals
vs 2047 sorts at 289K, 133x partitioning speedup on-chip)."""
from __future__ import annotations

import jax

from repro import core
from benchmarks.common import emit, scene_cloud, time_jit


def run(quick: bool = True):
    sizes = [1024, 33_000] if quick else [1024, 33_000, 289_000]
    th = {1024: 64, 33_000: 256, 289_000: 256}
    for n in sizes:
        pts = scene_cloud(0, n)
        base_us = None
        for strat in (core.FRACTAL, core.UNIFORM, core.OCTREE, core.KDTREE):
            # on_overflow silenced: no host callback inside a timed
            # executable (uniform at 289K overflows by design).
            fn = jax.jit(lambda p, s=strat: core.partition(
                p, th=th[n], strategy=s, on_overflow="silent"))
            us = time_jit(fn, pts)
            part = fn(pts)
            trav = int(part.traversals)
            sorts = int(part.sort_passes)
            if strat == core.KDTREE:
                base_us = us
            emit(f"partition/{strat}/n{n}", us,
                 f"traversals={trav};sorts={sorts};"
                 f"leaves={int(part.num_leaves)};"
                 f"max_block={int(part.max_leaf_vsize)}")
        frac_fn = jax.jit(lambda p: core.partition(p, th=th[n],
                                                   on_overflow="silent"))
        frac_us = time_jit(frac_fn, pts)
        emit(f"partition/speedup_vs_kdtree/n{n}", frac_us,
             f"kdtree_over_fractal={base_us / frac_us:.2f}x")

"""Shared benchmark utilities: timing, cloud generation, CSV emission."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

ROWS = []


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def time_jit(fn, *args, warmup=1, iters=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6  # us


def scene_cloud(seed: int, n: int):
    """Clustered scene cloud (S3DIS-like occupancy: walls + objects)."""
    rng = np.random.default_rng(seed)
    k = max(2, n // 4096)
    parts = []
    for i in range(k):
        c = rng.uniform(-4, 4, 3)
        s = rng.uniform(0.1, 0.8, 3)
        parts.append(rng.normal(c, s, (n // k, 3)))
    rest = n - sum(len(p) for p in parts)
    if rest:
        parts.append(rng.uniform(-4, 4, (rest, 3)))
    return jnp.asarray(np.concatenate(parts).astype(np.float32))

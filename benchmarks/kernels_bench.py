"""Paper §VI-C RSPU ablation analog: kernel-level costs and reuse factors.

``impl`` picks the timed backend.  On CPU the default is the XLA path (the
Pallas kernels are TPU-targeted and interpret-mode timing is meaningless);
pass ``impl="pallas"`` on TPU for compiled-kernel rows.  The kernels are
*verified* against their oracles here and their data-reuse model is derived:
intra-block parallelism shares one parent window across all centers of a
block (paper: 7.6x memory-access reduction for neighbor search), and the
FPS mask pinning replaces the window-check skip."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from benchmarks.common import emit, time_jit


def run(quick: bool = True, impl: str | None = None):
    impl = ops.resolve_impl(impl, default="xla")
    nb, bs, w, kc, num = (64, 256, 512, 64, 16)
    rng = np.random.default_rng(0)
    coords = jnp.asarray(rng.normal(0, 1, (nb, bs, 3)).astype(np.float32))
    mask = jnp.ones((nb, bs), bool)
    win = jnp.asarray(rng.normal(0, 1, (nb, w, 3)).astype(np.float32))
    wmask = jnp.ones((nb, w), bool)
    centers = win[:, :kc, :]
    cmask = jnp.ones((nb, kc), bool)

    us = time_jit(lambda: ops.fps_blocks(coords, mask, k=64, impl=impl))
    emit(f"kernels/fps_blocks/{impl}", us, f"nb{nb}_bs{bs}_k64")
    us = time_jit(lambda: ops.ball_query_blocks(
        centers, cmask, win, wmask, radius=0.5, num=num, impl=impl))
    emit(f"kernels/ball_query_blocks/{impl}", us, f"nb{nb}_kc{kc}_w{w}")
    us = time_jit(lambda: ops.knn_blocks(centers, win, wmask, k=3,
                                         impl=impl))
    emit(f"kernels/knn_blocks/{impl}", us, "")
    feats = jnp.asarray(rng.normal(0, 1, (nb, w, 64)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, w, (nb, 128)), jnp.int32)
    us = time_jit(lambda: ops.gather_blocks(feats, idx, impl=impl))
    emit(f"kernels/gather_blocks/{impl}", us, "")

    # Pallas interpret-mode equivalence (correctness, not speed).
    a = ops.fps_blocks(coords[:4], mask[:4], k=16, impl="pallas")
    b = ops.fps_blocks(coords[:4], mask[:4], k=16, impl="xla")
    ok = bool((np.asarray(a) == np.asarray(b)).all())
    emit("kernels/pallas_interpret_equiv", 0.0, f"fps_match={ok}")

    # Data-reuse model (paper: RSPU intra-block parallelism).
    naive_reads = kc * w * 12          # each center streams the window
    reuse_reads = w * 12               # window resident once per block
    emit("kernels/window_reuse_model", 0.0,
         f"naive={naive_reads};reused={reuse_reads};"
         f"reduction={naive_reads / reuse_reads:.1f}x")
    return impl  # resolved backend, recorded in the bench JSON meta

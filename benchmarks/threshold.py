"""Paper Fig. 17: threshold (th) trade-off — speedup vs accuracy proxies.

Lower th -> more, smaller blocks -> faster point ops but degraded FPS
coverage / neighbor recall (the paper's >8% loss at th=8, 4.6x-only speedup
at th=4k; sweet spots th=64 cls / 256 seg)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro.core import ref
from benchmarks.common import emit, scene_cloud, time_jit


def run(quick: bool = True):
    n = 4096 if quick else 33_000
    ths = [16, 64, 256] if quick else [8, 16, 64, 256, 1024]
    pts = scene_cloud(2, n)
    pts_np = np.asarray(pts)
    valid = jnp.ones((n,), bool)
    k = n // 4
    radius, num = 0.25, 16

    gi, _ = jax.jit(lambda p: ref.fps(p, valid, k))(pts)
    d_all = ((pts_np[:, None, :] - pts_np[None, np.asarray(gi), :]) ** 2
             ).sum(-1)
    cov_global = float(np.sqrt(d_all.min(1)).mean())

    for th in ths:
        def pipeline(p, th=th):
            part = core.partition(p, th=th, on_overflow="silent")
            samp = core.blockwise_fps(part, rate=0.25, k_out=k, bs=th)
            nb = core.blockwise_ball_query(part, samp, radius=radius,
                                           num=num, w=2 * th)
            return part, samp, nb

        us = time_jit(jax.jit(pipeline), pts)
        part, samp, nb = jax.jit(pipeline)(pts)
        sval = np.asarray(samp.valid)
        sel = np.asarray(part.coords)[np.asarray(samp.idx)[sval]]
        d = ((pts_np[:, None, :] - sel[None, :, :]) ** 2).sum(-1)
        cov = float(np.sqrt(d.min(1)).mean())

        centers = jnp.asarray(sel)
        g_idx, g_cnt = ref.ball_query(part.coords, part.valid, centers,
                                      jnp.ones(len(sel), bool), radius, num)
        g_idx, g_cnt = np.asarray(g_idx), np.asarray(g_cnt)
        b_idx = np.asarray(nb.idx)[sval]
        b_msk = np.asarray(nb.mask)[sval]
        recalls = []
        for i in range(min(len(sel), 512)):
            gset = set(g_idx[i][:min(g_cnt[i], num)].tolist())
            if gset:
                recalls.append(
                    len(gset & set(b_idx[i][b_msk[i]].tolist())) / len(gset))
        emit(f"threshold/th{th}/n{n}", us,
             f"coverage_ratio={cov / cov_global:.3f};"
             f"bq_recall={np.mean(recalls):.3f};"
             f"leaves={int(part.num_leaves)}")

"""Serving perf trajectory (docs/DESIGN.md §9): per-bucket latency
percentiles and sustained throughput for a mixed-size request stream
through ``repro.serve``.

Each impl serves the same stream: clouds padded to their minimal bucket,
fixed microbatches, plan cache warmed *before* the stream so latencies
exclude compile (compile time gets its own row).  With no ``--impl`` both
backends run, so one ``BENCH_serve.json`` carries the xla and pallas
trajectories side by side (off-TPU pallas runs in interpret mode —
correctness path, wall-clock not meaningful).

Rows (see benchmarks/README.md for the schema):
  serve/<impl>/bucket<n>/p50|p95|p99   latency percentiles (us_per_call)
  serve/<impl>/bucket<n>/throughput    derived clouds_per_s
  serve/<impl>/compile/n<n>            warmup compile (excluded above)
  serve/<impl>/stream                  whole-stream throughput + cache

CLI (the CI smoke leg):
  PYTHONPATH=src python -m benchmarks.serve_bench --requests 8 --n 4096 \
      --json bench_out
"""
from __future__ import annotations

import argparse

import jax

from benchmarks.common import emit
from repro.kernels import ops as kops


def _serve_stream(impl, *, buckets, requests, microbatch, th, mesh):
    from repro import serve
    from repro.data import synthetic

    # Generous deadline: batches dispatch when *full* (the packed-path
    # numbers this suite tracks); the tail flushes partial at stream end.
    cfg = serve.ServeConfig(buckets=buckets, microbatch=microbatch,
                            max_wait_s=60.0, th=th, impl=impl, mesh=mesh)
    engine = serve.ServeEngine(cfg)
    compile_s = engine.warm()
    for r, n in enumerate(serve.mixed_request_sizes(buckets, requests)):
        clouds, _ = synthetic.segmentation_batch(0, r, 1, n)
        engine.submit(clouds[0])
        for done in engine.step():
            engine.take(done)
    for done in engine.flush():
        engine.take(done)
    return engine.stats(), compile_s


def run(quick: bool = True, impl: str | None = None, *,
        requests: int | None = None, buckets: tuple | None = None,
        microbatch: int | None = None, th: int = 256, mesh: str = "none"):
    impls = ([kops.resolve_impl(impl)] if impl is not None
             else ["xla", "pallas"])
    buckets = buckets or ((1024, 4096) if quick else (4096, 16384, 65536))
    requests = requests or (8 if quick else 32)
    microbatch = microbatch or (2 if quick else 4)
    note = "" if jax.default_backend() == "tpu" else "interpret_mode"
    for im in impls:
        st, compile_s = _serve_stream(im, buckets=buckets,
                                      requests=requests,
                                      microbatch=microbatch, th=th,
                                      mesh=mesh)
        for b, s in compile_s.items():
            emit(f"serve/{im}/compile/n{b}", s * 1e6,
                 "excluded_from_latency")
        for b, row in sorted(st["buckets"].items()):
            for pct in ("p50", "p95", "p99"):
                emit(f"serve/{im}/bucket{b}/{pct}", row[f"{pct}_ms"] * 1e3,
                     f"count={row['count']}"
                     + (f";{note}" if note and im == "pallas" else ""))
            if row["clouds_per_s"] is not None:
                emit(f"serve/{im}/bucket{b}/throughput", 0.0,
                     f"clouds_per_s={row['clouds_per_s']:.4g}")
        pc = st["plan_cache"]
        one_trace = all(v == 1 for v in pc["traces"].values())
        if st["clouds_per_s"] is None:
            # No microbatch completed: stats() reports None rather than a
            # clamp-divided absurdity — nothing to emit for throughput.
            emit(f"serve/{im}/stream", 0.0,
                 f"clouds_per_s=none;executables={pc['executables']};"
                 f"one_trace_per_key={one_trace}")
        else:
            emit(f"serve/{im}/stream", 0.0,
                 f"clouds_per_s={st['clouds_per_s']:.4g};"
                 f"mpts_per_s={st['mpts_per_s']:.4g};"
                 f"executables={pc['executables']};"
                 f"one_trace_per_key={one_trace}")
    return ",".join(impls)  # backend(s) that ran, for the JSON meta


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--n", type=int, default=4096,
                    help="largest bucket; the ladder is (n//4, n)")
    ap.add_argument("--buckets", default=None,
                    help="explicit comma-separated ladder (overrides --n)")
    ap.add_argument("--microbatch", type=int, default=2)
    ap.add_argument("--th", type=int, default=256)
    ap.add_argument("--impl", default=None, choices=["xla", "pallas"],
                    help="default: both backends")
    ap.add_argument("--mesh", default="none", choices=["none", "auto"],
                    help="auto: shard microbatches over the elastic host "
                         "mesh (XLA logs involuntary-remat warnings for "
                         "the gather-heavy point ops on CPU)")
    ap.add_argument("--json", default=None, metavar="DIR",
                    help="write BENCH_serve.json into DIR")
    args = ap.parse_args(argv)

    buckets = (tuple(int(b) for b in args.buckets.split(","))
               if args.buckets else (max(1, args.n // 4), args.n))
    from benchmarks import common
    from benchmarks.run import _write_suite_json
    import sys
    import time

    quick = max(buckets) < 65_536  # paper-scale ladders are not CI smoke
    print("name,us_per_call,derived")
    t0 = time.time()
    ran = run(quick=quick, impl=args.impl, requests=args.requests,
              buckets=buckets, microbatch=args.microbatch, th=args.th,
              mesh=args.mesh)
    if args.json:
        path = _write_suite_json(args.json, "serve", common.ROWS,
                                 {"quick": quick, "impl": ran,
                                  "elapsed_s": round(time.time() - t0, 3),
                                  "unix_time": int(t0)})
        print(f"# wrote {path}", file=sys.stderr)


if __name__ == "__main__":
    main()

"""Training perf trajectory: PNN step time, forward vs forward+backward,
for both point-op execute backends.

The comparison the VJP layer (kernels/vjp.py, docs/DESIGN.md §4) makes
meaningful: with ``impl="pallas"`` the backward pass runs through the
kernels too (gather's transposed one-hot scatter-add; index producers
contribute zero cotangents), so fwd+bwd/fwd ratios are comparable across
impls instead of the pallas column silently falling back to the oracle.
Off-TPU the pallas rows run in interpret mode — correctness trajectory,
wall-clock not meaningful (flagged in ``derived``).

Rows (benchmarks/README.md has the BENCH_<suite>.json schema):
  train/<impl>/fwd            jitted forward (loss only)
  train/<impl>/fwd_bwd        jitted value_and_grad
  train/<impl>/step           full AdamW step (grad + update)
  train/<impl>/loss_drop      loss over ``steps`` fixed-batch steps

CLI (the CI train-smoke leg):
  PYTHONPATH=src python -m benchmarks.train_bench --steps 3 --json bench_out
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_jit
from repro.kernels import ops as kops


def _bench_impl(im, *, n, th, batch, steps, note):
    from repro.data import synthetic
    from repro.models import pnn
    from repro.train import optimizer as opt_lib
    from repro.train.pnn import loss_fn, make_train_step

    mcfg = pnn.pointnet2_cls(n=n, point_ops="bppo", th=th, impl=im)
    params = pnn.init(jax.random.PRNGKey(0), mcfg)
    pts, labels = synthetic.classification_batch(0, 0, batch, n)
    data = {"points": pts, "labels": labels}
    tag = f";{note}" if note else ""

    fwd = jax.jit(lambda p, b: loss_fn(p, mcfg, b)[0])
    us = time_jit(fwd, params, data)
    emit(f"train/{im}/fwd", us, f"n={n};batch={batch}{tag}")

    fwd_bwd = jax.jit(jax.value_and_grad(
        lambda p, b: loss_fn(p, mcfg, b)[0]))
    us_fb = time_jit(fwd_bwd, params, data)
    emit(f"train/{im}/fwd_bwd", us_fb,
         f"bwd_over_fwd={us_fb / max(us, 1e-9):.2f}{tag}")

    opt_cfg = opt_lib.OptConfig(lr=3e-3, warmup=0, total_steps=steps,
                                weight_decay=0.0)
    step = make_train_step(mcfg, opt_cfg)
    opt = opt_lib.init(params)
    us_step = time_jit(lambda p, o, b: step(p, o, b)[2]["loss"],
                       params, opt, data)
    emit(f"train/{im}/step", us_step, f"optimizer=adamw{tag}")

    p, o = params, opt
    losses = []
    for _ in range(steps):
        p, o, metrics = step(p, o, data)
        losses.append(float(metrics["loss"]))
    emit(f"train/{im}/loss_drop", 0.0,
         f"loss0={losses[0]:.4f};lossN={losses[-1]:.4f};steps={steps}")


def run(quick: bool = True, impl: str | None = None, *,
        n: int | None = None, th: int | None = None,
        batch: int | None = None, steps: int | None = None):
    impls = ([kops.resolve_impl(impl)] if impl is not None
             else ["xla", "pallas"])
    n = n or (192 if quick else 1024)
    th = th or (32 if quick else 64)
    batch = batch or (4 if quick else 16)
    steps = steps or (3 if quick else 20)
    note = "" if jax.default_backend() == "tpu" else "interpret_mode"
    for im in impls:
        _bench_impl(im, n=n, th=th, batch=batch, steps=steps,
                    note=note if im == "pallas" else "")
    return ",".join(impls)  # backend(s) that ran, for the JSON meta


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=192)
    ap.add_argument("--th", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--impl", default=None, choices=["xla", "pallas"],
                    help="default: both backends")
    ap.add_argument("--json", default=None, metavar="DIR",
                    help="write BENCH_train.json into DIR")
    args = ap.parse_args(argv)

    from benchmarks import common
    from benchmarks.run import _write_suite_json
    import sys
    import time

    quick = args.n <= 512
    print("name,us_per_call,derived")
    t0 = time.time()
    ran = run(quick=quick, impl=args.impl, n=args.n, th=args.th,
              batch=args.batch, steps=args.steps)
    if args.json:
        path = _write_suite_json(args.json, "train", common.ROWS,
                                 {"quick": quick, "impl": ran,
                                  "elapsed_s": round(time.time() - t0, 3),
                                  "unix_time": int(t0)})
        print(f"# wrote {path}", file=sys.stderr)


if __name__ == "__main__":
    main()

"""Paper Figs. 4/13/15/18: global vs block-parallel point operations.

Measures FPS / ball-query / interpolation / gather in both modes and the
scaling of the global-search O(n^2) cost with input size — the bottleneck
shift the paper targets (point ops: 30% of runtime at 1K -> >90% at 289K).
Also derives the memory-traffic model: global ops touch n points per
iteration; block ops touch <= 2*th (the paper's on-chip window)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import core
from repro.core import ref
from benchmarks.common import emit, scene_cloud, time_jit


def run(quick: bool = True):
    sizes = [1024, 8192] if quick else [1024, 8192, 33_000, 131_072]
    th = 256
    rate, radius, num = 0.25, 0.2, 16
    for n in sizes:
        pts = scene_cloud(1, n)
        valid = jnp.ones((n,), bool)
        k = n // 4

        # --- global (PointAcc-style baseline) ---
        g_fps = jax.jit(lambda p: ref.fps(p, valid, k)[0])
        us_gfps = time_jit(g_fps, pts)
        sidx = g_fps(pts)
        centers = pts[sidx]
        g_bq = jax.jit(lambda p, c: ref.ball_query(
            p, valid, c, jnp.ones((k,), bool), radius, num)[0])
        us_gbq = time_jit(g_bq, pts, centers)
        feats = jnp.ones((k, 64), jnp.float32)
        g_int = jax.jit(lambda p, c, f: ref.interpolate_3nn(
            p, c, jnp.ones((k,), bool), f)[0])
        us_gint = time_jit(g_int, pts, centers, feats)

        # --- block-parallel (FractalCloud) ---
        def bw_pipeline(p):
            part = core.partition(p, th=th)
            samp = core.blockwise_fps(part, rate=rate, k_out=k, bs=th)
            return part, samp

        part, samp = jax.jit(bw_pipeline)(pts)
        b_fps = jax.jit(lambda p: core.blockwise_fps(
            core.partition(p, th=th), rate=rate, k_out=k, bs=th).idx)
        us_bfps = time_jit(b_fps, pts)

        def _bq(p):
            part = core.partition(p, th=th)
            samp = core.blockwise_fps(part, rate=rate, k_out=k, bs=th)
            return core.blockwise_ball_query(part, samp, radius=radius,
                                             num=num, w=2 * th).idx

        us_bbq = time_jit(jax.jit(_bq), pts)

        def b_int(p, f):
            part = core.partition(p, th=th)
            samp = core.blockwise_fps(part, rate=rate, k_out=k, bs=th)
            return core.blockwise_interpolate(part, samp, f, wc=128,
                                              bs=th)[0]

        us_bint = time_jit(jax.jit(b_int), pts, feats)

        emit(f"point_ops/fps/global/n{n}", us_gfps,
             f"speedup={us_gfps / us_bfps:.2f}x_blockwise")
        emit(f"point_ops/fps/blockwise/n{n}", us_bfps, "includes_partition")
        emit(f"point_ops/ballquery/global/n{n}", us_gbq,
             f"speedup={us_gbq / us_bbq:.2f}x_blockwise")
        emit(f"point_ops/ballquery/blockwise/n{n}", us_bbq,
             "includes_partition+fps")
        emit(f"point_ops/interp/global/n{n}", us_gint,
             f"speedup={us_gint / us_bint:.2f}x_blockwise")
        emit(f"point_ops/interp/blockwise/n{n}", us_bint, "")

        # memory-traffic model (paper Fig. 15): bytes touched per op
        g_traffic = k * n * 12          # every center scans the cloud
        b_traffic = k * 2 * th * 12     # every center scans its window
        emit(f"point_ops/traffic_model/n{n}", 0.0,
             f"global_bytes={g_traffic};block_bytes={b_traffic};"
             f"reduction={g_traffic / b_traffic:.1f}x")

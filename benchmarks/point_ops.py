"""Paper Figs. 4/13/15/18: global vs block-parallel point operations.

Measures FPS / ball-query / interpolation in both modes and the scaling of
the global-search O(n^2) cost with input size — the bottleneck shift the
paper targets (point ops: 30% of runtime at 1K -> >90% at 289K).  The
Fractal partition is timed as its own row so per-op rows measure only the
op (the partition is built once and reused by every BPPO op of a layer).
Also derives the memory-traffic model: global ops touch n points per
iteration; block ops touch <= 2*th (the paper's on-chip window).

``impl`` selects the BPPO execute backend (xla | pallas); pallas rows off
TPU run in interpret mode (correctness path, wall-clock not meaningful).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import core
from repro.core import ref
from repro.kernels import ops as kops
from benchmarks.common import emit, scene_cloud, time_jit


def run(quick: bool = True, impl: str | None = None):
    impl = kops.resolve_impl(impl, default="xla")
    note = "" if jax.default_backend() == "tpu" or impl == "xla" \
        else "interpret_mode"
    sizes = [1024, 8192] if quick else [1024, 8192, 33_000, 131_072]
    th = 256
    rate, radius, num = 0.25, 0.2, 16
    for n in sizes:
        pts = scene_cloud(1, n)
        valid = jnp.ones((n,), bool)
        k = n // 4

        # --- global (PointAcc-style baseline) ---
        g_fps = jax.jit(lambda p: ref.fps(p, valid, k)[0])
        us_gfps = time_jit(g_fps, pts)
        sidx = g_fps(pts)
        centers = pts[sidx]
        g_bq = jax.jit(lambda p, c: ref.ball_query(
            p, valid, c, jnp.ones((k,), bool), radius, num)[0])
        us_gbq = time_jit(g_bq, pts, centers)
        feats = jnp.ones((k, 64), jnp.float32)
        g_int = jax.jit(lambda p, c, f: ref.interpolate_3nn(
            p, c, jnp.ones((k,), bool), f)[0])
        us_gint = time_jit(g_int, pts, centers, feats)

        # --- block-parallel (FractalCloud), each op timed on its own ---
        # The value-producing call doubles as the compile warmup.
        part_fn = jax.jit(lambda p: core.partition(p, th=th,
                                                   on_overflow="silent"))
        part = jax.block_until_ready(part_fn(pts))
        us_part = time_jit(part_fn, pts, warmup=0)

        fps_fn = jax.jit(lambda pt: core.blockwise_fps(
            pt, rate=rate, k_out=k, bs=th, impl=impl))
        samp = jax.block_until_ready(fps_fn(part))
        us_bfps = time_jit(fps_fn, part, warmup=0)

        bq_fn = jax.jit(lambda pt, sm: core.blockwise_ball_query(
            pt, sm, radius=radius, num=num, w=2 * th, impl=impl).idx)
        us_bbq = time_jit(bq_fn, part, samp)

        int_fn = jax.jit(lambda pt, sm, f: core.blockwise_interpolate(
            pt, sm, f, wc=128, bs=th, impl=impl)[0])
        us_bint = time_jit(int_fn, part, samp, feats)

        emit(f"point_ops/partition/n{n}", us_part, "shared_by_all_bppo_ops")
        emit(f"point_ops/fps/global/n{n}", us_gfps,
             f"speedup={us_gfps / us_bfps:.2f}x_blockwise")
        emit(f"point_ops/fps/blockwise/{impl}/n{n}", us_bfps, note)
        emit(f"point_ops/ballquery/global/n{n}", us_gbq,
             f"speedup={us_gbq / us_bbq:.2f}x_blockwise")
        emit(f"point_ops/ballquery/blockwise/{impl}/n{n}", us_bbq, note)
        emit(f"point_ops/interp/global/n{n}", us_gint,
             f"speedup={us_gint / us_bint:.2f}x_blockwise")
        emit(f"point_ops/interp/blockwise/{impl}/n{n}", us_bint, note)

        # memory-traffic model (paper Fig. 15): bytes touched per op
        g_traffic = k * n * 12          # every center scans the cloud
        b_traffic = k * 2 * th * 12     # every center scans its window
        emit(f"point_ops/traffic_model/n{n}", 0.0,
             f"global_bytes={g_traffic};block_bytes={b_traffic};"
             f"reduction={g_traffic / b_traffic:.1f}x")
    return impl  # resolved backend, recorded in the bench JSON meta

"""Scene-scale inference trajectory (docs/DESIGN.md §10): points/s and
peak-memory scaling for ``repro.scene`` across scene sizes.

This is the workload the paper's "large-scale" claim is about: a single
100k–1M-point cloud segmented end to end without ever materializing an
O(n²) point op — the scene is tiled into fixed-shape blocks, tiles stream
through the bucketed serving engine (one executable per bucket, compiled
in ``warm()`` and excluded from the timings), and logits stitch back by
owner tile.  Peak RSS is reported per size so the memory trajectory is
visibly sublinear in n² (tile tensors are O(tile_points), the output is
O(n)); wall-clock covers tiling + dispatch + stitch.

Rows (see benchmarks/README.md):
  scene/<impl>/n<k>/infer       end-to-end µs; derived points_per_s, tiles,
                                halo_points, peak_rss_mb
  scene/<impl>/n<k>/compile     warm() compile seconds (excluded above)

CLI (the CI scene-smoke leg):
  PYTHONPATH=src python -m benchmarks.scene_bench --n 16384 --json bench_out
"""
from __future__ import annotations

import argparse
import multiprocessing
import resource
import time

from benchmarks.common import emit
from repro.kernels import ops as kops


def _peak_rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def _one_scene(impl, n, tile_points, th, halo, microbatch, mesh):
    """One (impl, n) measurement; run in its own process (see run())."""
    import jax

    from repro import scene
    from repro.data import synthetic

    coords, _ = synthetic.scene(0, n)
    cfg = scene.SceneConfig(tile_points=tile_points, halo=halo, th=th,
                            impl=impl, microbatch=microbatch, mesh=mesh)
    eng = scene.SceneEngine(cfg)
    t0 = time.monotonic()
    eng.warm()
    compile_s = time.monotonic() - t0
    t0 = time.monotonic()
    logits, plan = eng.infer(coords)
    dt = time.monotonic() - t0
    assert logits.shape == (n, cfg.num_classes)
    return {"dt": dt, "compile_s": compile_s, "tiles": plan.num_tiles,
            "halo_points": plan.halo_points, "max_tile": plan.max_tile_n,
            "peak_rss_mb": _peak_rss_mb(),
            "backend": jax.default_backend()}


def run(quick: bool = True, impl: str | None = None, *,
        ns: tuple | None = None, tile_points: int = 4096, th: int = 256,
        halo: float = 0.1, microbatch: int = 4, mesh: str = "none"):
    impls = ([kops.resolve_impl(impl)] if impl is not None
             else ["xla", "pallas"])
    ns = ns or ((16_384,) if quick else (16_384, 65_536, 262_144))
    # One spawned process per (impl, n): ru_maxrss is a process-lifetime
    # high-watermark, so in-process runs would inherit the peak of every
    # prior size and flatten the memory-scaling trajectory this suite
    # exists to show.
    ctx = multiprocessing.get_context("spawn")
    for im in impls:
        for n in ns:
            with ctx.Pool(1) as pool:
                m = pool.apply(_one_scene, (im, n, tile_points, th, halo,
                                            microbatch, mesh))
            note = "" if m["backend"] == "tpu" else "interpret_mode"
            emit(f"scene/{im}/n{n}/compile", m["compile_s"] * 1e6,
                 "excluded_from_infer")
            emit(f"scene/{im}/n{n}/infer", m["dt"] * 1e6,
                 f"points_per_s={n / m['dt']:.4g};tiles={m['tiles']};"
                 f"halo_points={m['halo_points']};"
                 f"max_tile={m['max_tile']};"
                 f"peak_rss_mb={m['peak_rss_mb']:.0f}"
                 + (f";{note}" if note and im == "pallas" else ""))
    return ",".join(impls)  # backend(s) that ran, for the JSON meta


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", default="16384",
                    help="comma-separated scene sizes")
    ap.add_argument("--tile-points", type=int, default=4096)
    ap.add_argument("--th", type=int, default=256)
    ap.add_argument("--halo", type=float, default=0.1)
    ap.add_argument("--microbatch", type=int, default=4)
    ap.add_argument("--impl", default=None, choices=["xla", "pallas"],
                    help="default: both backends")
    ap.add_argument("--mesh", default="none", choices=["none", "auto"],
                    help="auto: shard tile microbatches over the elastic "
                         "host mesh")
    ap.add_argument("--json", default=None, metavar="DIR",
                    help="write BENCH_scene.json into DIR")
    args = ap.parse_args(argv)

    import sys

    from benchmarks import common
    from benchmarks.run import _write_suite_json

    ns = tuple(int(x) for x in args.n.split(","))
    quick = max(ns) < 262_144      # paper-scale runs are not CI smoke
    print("name,us_per_call,derived")
    t0 = time.time()
    ran = run(quick=quick, impl=args.impl, ns=ns,
              tile_points=args.tile_points, th=args.th, halo=args.halo,
              microbatch=args.microbatch, mesh=args.mesh)
    if args.json:
        path = _write_suite_json(args.json, "scene", common.ROWS,
                                 {"quick": quick, "impl": ran,
                                  "elapsed_s": round(time.time() - t0, 3),
                                  "unix_time": int(t0)})
        print(f"# wrote {path}", file=sys.stderr)


if __name__ == "__main__":
    main()

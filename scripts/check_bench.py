#!/usr/bin/env python
"""Gate a fresh BENCH_<suite>.json against the committed snapshot.

``benchmarks/history/`` holds one committed ``BENCH_<suite>.json`` per
suite — the perf trajectory the repo promises.  CI regenerates the suite
and runs::

    python scripts/check_bench.py bench_out/BENCH_serve.json --tolerance 4.0

Rows are matched by ``name``.  Both sides are clamped up to the
``--min-us`` floor before the ratio is taken, so sub-floor jitter on
shared CI runners never gates, while a genuinely fast row blowing up past
the floor still does.  A row fails when the clamped ratio exceeds
``tolerance``.  A row present in the snapshot but missing from the fresh run
fails too — a benchmark silently disappearing is itself a regression.
New rows are reported but pass (they have no baseline yet); commit them
with ``--update``.

``--update`` rewrites the snapshot from the fresh payload (the blessed
way to move the baseline after a deliberate perf change).
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys

DEFAULT_HISTORY = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "benchmarks", "history")


def load_rows(payload: dict) -> dict:
    return {r["name"]: float(r["us_per_call"]) for r in payload["rows"]}


def compare(new: dict, old: dict, tolerance: float, min_us: float):
    """Returns (failures, notes): failures gate, notes are informational."""
    failures, notes = [], []
    for name, old_us in sorted(old.items()):
        if name not in new:
            failures.append(f"{name}: in snapshot ({old_us:.1f} us) but "
                            f"missing from the fresh run")
            continue
        new_us = new[name]
        # Clamp to the floor: jitter among sub-floor timings never gates,
        # but a fast row regressing far past the floor still does.
        ratio = max(new_us, min_us) / max(old_us, min_us)
        line = f"{name}: {old_us:.1f} -> {new_us:.1f} us ({ratio:.2f}x)"
        if ratio > tolerance:
            failures.append(line + f" exceeds tolerance {tolerance:.1f}x")
        else:
            notes.append(line)
    for name in sorted(set(new) - set(old)):
        notes.append(f"{name}: new row ({new[name]:.1f} us), no baseline "
                     f"yet — commit with --update")
    return failures, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="compare a fresh BENCH_<suite>.json to the committed "
                    "snapshot in benchmarks/history/")
    ap.add_argument("fresh", help="path to the freshly generated "
                                  "BENCH_<suite>.json")
    ap.add_argument("--history", default=DEFAULT_HISTORY,
                    help="snapshot directory (default: benchmarks/history)")
    ap.add_argument("--tolerance", type=float, default=1.5,
                    help="max allowed slowdown ratio (default 1.5; CI uses "
                         "a generous 4.0 for shared runners)")
    ap.add_argument("--min-us", type=float, default=200.0,
                    help="noise floor: timings are clamped up to this "
                         "before the ratio is taken (default 200)")
    ap.add_argument("--update", action="store_true",
                    help="bless the fresh payload as the new snapshot")
    args = ap.parse_args(argv)

    with open(args.fresh) as f:
        payload = json.load(f)
    suite = payload.get("suite") or os.path.basename(
        args.fresh).removeprefix("BENCH_").removesuffix(".json")
    snap_path = os.path.join(args.history, f"BENCH_{suite}.json")

    if args.update or not os.path.exists(snap_path):
        os.makedirs(args.history, exist_ok=True)
        shutil.copyfile(args.fresh, snap_path)
        verb = "updated" if args.update else "created (no prior snapshot)"
        print(f"check_bench[{suite}]: {verb} {snap_path}")
        return 0

    with open(snap_path) as f:
        snapshot = json.load(f)
    failures, notes = compare(load_rows(payload), load_rows(snapshot),
                              args.tolerance, args.min_us)
    for line in notes:
        print(f"check_bench[{suite}]: {line}")
    for line in failures:
        print(f"check_bench[{suite}]: FAIL {line}", file=sys.stderr)
    print(f"check_bench[{suite}]: {len(failures)} failure(s), "
          f"{len(notes)} row(s) ok (tolerance {args.tolerance:.1f}x, "
          f"floor {args.min_us:.0f} us)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())

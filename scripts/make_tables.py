"""Render EXPERIMENTS.md tables from the dry-run/perf JSON outputs."""
import json
import sys


def roofline_md(path):
    data = json.load(open(path))
    rows = [r for r in data["rows"] if "skipped" not in r]
    skips = [r for r in data["rows"] if "skipped" in r]
    out = ["| arch | shape | mesh | t_comp (ms) | t_mem (ms) | t_coll (ms) "
           "| bound | useful | roofline | peak GB/dev | fits 16G |",
           "|---|---|---|---|---|---|---|---|---|---|---|"[:110]]
    out[1] = "|---|---|---|---:|---:|---:|---|---:|---:|---:|---|"
    for r in rows:
        peak = r["mem_per_device"]["peak_mb"] / 1024
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']*1e3:.2f} | {r['t_memory_s']*1e3:.2f} "
            f"| {r['t_collective_s']*1e3:.2f} | {r['bottleneck']} "
            f"| {r['usefulness']*100:.0f}% "
            f"| {r['roofline_fraction']*100:.1f}% | {peak:.2f} "
            f"| {'yes' if peak <= 16 else 'NO'} |")
    for r in skips:
        out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                   f"| — | — | — | skipped | — | — | — | — |")
    return "\n".join(out)


if __name__ == "__main__":
    print(roofline_md(sys.argv[1]))

"""Step functions + input specs for every (arch x shape) cell.

``train_step`` / ``prefill_step`` / ``decode_step`` are what the launcher
jits with in/out shardings; ``input_specs`` builds the ShapeDtypeStruct
stand-ins for the dry-run (weak-type-correct, shardable, no allocation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.shapes import ShapeSpec
from repro.lm import model as M
from repro.train import optimizer as opt_lib

Array = jax.Array


def loss_fn(params, cfg, batch):
    if cfg.encoder_layers:
        hidden, aux = M.forward(params, cfg, frames=batch["frames"],
                                dec_tokens=batch["dec_tokens"])
        targets = batch["labels"]
    elif cfg.frontend == "embeddings":
        hidden, aux = M.forward(params, cfg, frames=batch["frames"])
        targets = batch["labels"]
    else:
        hidden, aux = M.forward(params, cfg, tokens=batch["tokens"])
        targets = batch["labels"]
    loss = M.lm_loss(params, cfg, hidden, targets,
                     batch.get("loss_mask"))
    return loss + aux, (loss, aux)


def make_train_step(cfg, opt_cfg: opt_lib.OptConfig, microbatch: int = 0):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    ``microbatch > 0`` accumulates gradients over that many slices of the
    batch (sequential scan) — activation memory control at fixed global
    batch."""

    def grads_of(params, batch):
        (tot, (loss, aux)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, cfg, batch)
        return grads, loss, aux

    def train_step(params, opt_state, batch):
        if microbatch and microbatch > 1:
            def sl(x, i):
                mb = x.shape[0] // microbatch
                return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, 0)

            def body(carry, i):
                acc, ls, ax = carry
                g, l, a = grads_of(params,
                                   jax.tree.map(lambda x: sl(x, i), batch))
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, ls + l, ax + a), None

            zero = jax.tree.map(
                lambda x: jnp.zeros(x.shape, jnp.float32), params)
            (grads, loss, aux), _ = jax.lax.scan(
                body, (zero, jnp.zeros(()), jnp.zeros(())),
                jnp.arange(microbatch))
            grads = jax.tree.map(lambda g: g / microbatch, grads)
            loss, aux = loss / microbatch, aux / microbatch
        else:
            grads, loss, aux = grads_of(params, batch)
        params, opt_state, om = opt_lib.update(opt_cfg, grads, opt_state,
                                               params)
        metrics = {"loss": loss, "aux": aux, **om}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg, max_len=None):
    def prefill_step(params, batch):
        return M.prefill(params, cfg,
                         tokens=batch.get("tokens"),
                         frames=batch.get("frames"),
                         dec_tokens=batch.get("dec_tokens"),
                         max_len=max_len)

    return prefill_step


def make_decode_step(cfg):
    def decode_step(params, token, cache, pos):
        return M.decode_step(params, cfg, token, cache, pos)

    return decode_step


# ---------------------------------------------------------------------------
# Dry-run input specs (ShapeDtypeStruct only)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg, shape: ShapeSpec):
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if cfg.encoder_layers:
        return {"frames": _sds((b, s, cfg.d_model), jnp.float32),
                "dec_tokens": _sds((b, s), i32),
                "labels": _sds((b, s), i32)}
    if cfg.frontend == "embeddings":
        return {"frames": _sds((b, s, cfg.d_model), jnp.float32),
                "labels": _sds((b, s), i32)}
    return {"tokens": _sds((b, s), i32), "labels": _sds((b, s), i32)}


def prefill_specs(cfg, shape: ShapeSpec):
    sp = batch_specs(cfg, shape)
    sp.pop("labels")
    return sp


def decode_specs(cfg, shape: ShapeSpec):
    """(token, cache, pos) specs: one new token, KV/state cache at seq_len."""
    b, s = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(
        lambda: M.init_cache(None, cfg, b, s,
                             enc_len=s if cfg.encoder_layers else None))
    if cfg.frontend == "embeddings" and not cfg.encoder_layers:
        token = _sds((b, 1, cfg.d_model), jnp.float32)
    else:
        token = _sds((b, 1), jnp.int32)
    return token, cache, _sds((), jnp.int32)


def eval_shape_init(cfg):
    """(param ShapeDtypeStructs, logical-axes tree) without allocating.

    The axes tree is a pure-python by-product of tracing init, captured on
    the side (strings cannot flow through eval_shape outputs)."""
    box = {}

    def f():
        params, axes = M.init(jax.random.PRNGKey(0), cfg)
        box["axes"] = axes
        return params

    shapes = jax.eval_shape(f)
    return shapes, box["axes"]

"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel train, recurrent
decode) and sLSTM (scalar memory with block-diagonal recurrence, scanned).

The mLSTM chunkwise form is flash-attention-style: within a chunk the
exp-input-gate/sigmoid-forget-gate products are evaluated in log space with
a per-row running stabilizer; across chunks a scan carries (C, n, m) per
head.  Structurally faithful simplifications vs the reference blocks are
listed in docs/DESIGN.md §5 (xlstm row).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.dist.logical import lc
from repro.lm.layers import dense, dense_init, rmsnorm, rmsnorm_init

Array = jax.Array
NEGINF = -1.0e30


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    n_heads: int = 4
    proj_factor: float = 2.0     # mLSTM up-projection
    slstm_ff: float = 4.0 / 3.0  # sLSTM post-FFN
    chunk: int = 64
    unroll: bool = False         # unroll the chunk scan (metric compiles)

    def d_inner(self, d):
        return int(self.proj_factor * d)


# --- mLSTM -------------------------------------------------------------------

def mlstm_init(key, d, cfg: XLSTMConfig, dtype=jnp.float32):
    di = cfg.d_inner(d)
    keys = jax.random.split(key, 8)
    p, a = {}, {}
    p["up"], a["up"] = dense_init(keys[0], d, 2 * di, ("embed_fsdp", "ff"),
                                  dtype=dtype)
    for i, nm in enumerate(("wq", "wk", "wv")):
        p[nm], a[nm] = dense_init(keys[1 + i], di, di, ("ff", None),
                                  dtype=dtype)
    p["wif"], a["wif"] = dense_init(keys[4], di, 2 * cfg.n_heads,
                                    ("ff", None), dtype=dtype)
    p["if_b"] = jnp.concatenate([
        jnp.zeros((cfg.n_heads,)),            # input gate bias
        jnp.linspace(3.0, 6.0, cfg.n_heads),  # forget gate bias (open)
    ]).astype(dtype)
    a["if_b"] = (None,)
    p["norm"], a["norm"] = rmsnorm_init(di, dtype)
    p["down"], a["down"] = dense_init(keys[5], di, d, ("ff", "embed_fsdp"),
                                      dtype=dtype)
    return p, a


def _mlstm_gates(p, h, nh):
    pre = dense(p["wif"], h) + p["if_b"]
    li = pre[..., :nh].astype(jnp.float32)                   # log input gate
    lf = jax.nn.log_sigmoid(pre[..., nh:].astype(jnp.float32))
    return li, lf


def mlstm_forward(p, x, *, d, cfg: XLSTMConfig, return_state=False):
    b, s, _ = x.shape
    di, nh, L = cfg.d_inner(d), cfg.n_heads, cfg.chunk
    hd = di // nh
    up = dense(p["up"], x)
    hin, gate = up[..., :di], up[..., di:]
    q = dense(p["wq"], hin).reshape(b, s, nh, hd) * hd ** -0.5
    k = dense(p["wk"], hin).reshape(b, s, nh, hd) * hd ** -0.5
    v = dense(p["wv"], hin).reshape(b, s, nh, hd)
    li, lf = _mlstm_gates(p, hin, nh)                        # (B,S,H)

    # Pad to a chunk multiple; padded steps are identity (f=1, i=0).
    pad = (-s) % L
    if pad:
        zpad = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) *
                                 (t.ndim - 2))
        q, k, v = zpad(q), zpad(k), zpad(v)
        lf = zpad(lf)
        li = jnp.pad(li, ((0, 0), (0, pad), (0, 0)),
                     constant_values=NEGINF)
    sp = s + pad
    nc = sp // L
    shp = lambda t: t.reshape(b, nc, L, *t.shape[2:])
    q, k, v = shp(q), shp(k), shp(v)
    q = lc(q, "batch", None, None, "heads", None)
    li, lf = shp(li), shp(lf)
    lfc = jnp.cumsum(lf, axis=2)                             # (B,nc,L,H)
    causal = jnp.tril(jnp.ones((L, L), bool))[None, :, :, None]

    # cross-chunk carried state: C (B,H,dk,dv), n (B,H,dk), m (B,H).
    # Intra-chunk (L,L,H) score tensors are built INSIDE the scan body —
    # hoisting them materializes (B,nc,L,L,H) for every chunk at once
    # (42 GB/device at train_4k).
    def scanner(carry, inp):
        C, n, m = carry
        qc, kc, vc, lic, lfcc = inp
        scc = (lfcc[:, :, None, :] - lfcc[:, None, :, :]
               + lic[:, None, :, :])                         # (B,L,L,H)
        scc = jnp.where(causal, scc, NEGINF)
        mloc = jnp.max(scc, axis=2)                          # (B,L,H)
        qkc = jnp.einsum("blhd,bshd->blsh", qc.astype(jnp.float32),
                         kc.astype(jnp.float32))
        # inter log-decay for queries: lfc_t + m_prev
        b_inter = lfcc + m[:, None, :]                       # (B,L,H)
        mrow = jnp.maximum(mloc, b_inter)
        w_intra = jnp.exp(scc - mrow[:, :, None, :]) * qkc   # (B,L,L,H)
        y_num = jnp.einsum("blsh,bshd->blhd", w_intra,
                           vc.astype(jnp.float32))
        y_den = jnp.sum(w_intra, axis=2)                     # (B,L,H)
        w_inter = jnp.exp(b_inter - mrow)                    # (B,L,H)
        y_num += w_inter[..., None] * jnp.einsum(
            "blhk,bhkv->blhv", qc.astype(jnp.float32), C)
        y_den += w_inter * jnp.einsum(
            "blhk,bhk->blh", qc.astype(jnp.float32), n)
        denom = jnp.maximum(jnp.abs(y_den), jnp.exp(-mrow)) + 1e-6
        y = y_num / denom[..., None]
        # state update to end of chunk
        lfl = lfcc[:, -1, :]                                 # (B,H)
        dec_k = lfl[:, None, :] - lfcc + lic                 # (B,L,H)
        m_new = jnp.maximum(lfl + m, jnp.max(dec_k, axis=1))
        wk = jnp.exp(dec_k - m_new[:, None, :])
        C_new = (jnp.exp(lfl + m - m_new)[:, :, None, None] * C
                 + jnp.einsum("blh,blhk,blhv->bhkv", wk,
                              kc.astype(jnp.float32),
                              vc.astype(jnp.float32)))
        n_new = (jnp.exp(lfl + m - m_new)[:, :, None] * n
                 + jnp.einsum("blh,blhk->bhk", wk, kc.astype(jnp.float32)))
        return (C_new, n_new, m_new), y

    init = (jnp.zeros((b, nh, hd, hd), jnp.float32),
            jnp.zeros((b, nh, hd), jnp.float32),
            jnp.full((b, nh), -1e30, jnp.float32))
    mv = lambda t: jnp.moveaxis(t, 1, 0)
    (Cf, nf, mf), ys = jax.lax.scan(
        jax.checkpoint(scanner), init,
        (mv(q), mv(k), mv(v), mv(li), mv(lfc)),
        unroll=nc if cfg.unroll else 1)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, sp, di)[:, :s].astype(x.dtype)
    y = rmsnorm(p["norm"], y) * jax.nn.silu(gate)
    out = dense(p["down"], y)
    if return_state:
        return out, {"C": Cf, "n": nf, "m": mf}
    return out


def mlstm_state(batch, d, cfg: XLSTMConfig):
    nh = cfg.n_heads
    hd = cfg.d_inner(d) // nh
    return {"C": jnp.zeros((batch, nh, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, nh, hd), jnp.float32),
            "m": jnp.full((batch, nh), -1e30, jnp.float32)}


def mlstm_state_axes():
    return {"C": ("batch", "heads", None, None),
            "n": ("batch", "heads", None), "m": ("batch", "heads")}


def mlstm_decode(p, x, state, *, d, cfg: XLSTMConfig):
    b = x.shape[0]
    di, nh = cfg.d_inner(d), cfg.n_heads
    hd = di // nh
    up = dense(p["up"], x)
    hin, gate = up[..., :di], up[..., di:]
    q = dense(p["wq"], hin).reshape(b, nh, hd).astype(jnp.float32) * hd ** -0.5
    k = dense(p["wk"], hin).reshape(b, nh, hd).astype(jnp.float32) * hd ** -0.5
    v = dense(p["wv"], hin).reshape(b, nh, hd).astype(jnp.float32)
    li, lf = _mlstm_gates(p, hin, nh)
    li, lf = li[:, 0], lf[:, 0]                               # (B,H)
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(lf + m, li)
    fw = jnp.exp(lf + m - m_new)
    iw = jnp.exp(li - m_new)
    C = fw[:, :, None, None] * C + iw[:, :, None, None] * \
        jnp.einsum("bhk,bhv->bhkv", k, v)
    n = fw[:, :, None] * n + iw[:, :, None] * k
    num = jnp.einsum("bhk,bhkv->bhv", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q, n)),
                      jnp.exp(-m_new)) + 1e-6
    y = (num / den[..., None]).reshape(b, 1, di).astype(x.dtype)
    y = rmsnorm(p["norm"], y) * jax.nn.silu(gate)
    return dense(p["down"], y), {"C": C, "n": n, "m": m_new}


# --- sLSTM -------------------------------------------------------------------

def slstm_init(key, d, cfg: XLSTMConfig, dtype=jnp.float32):
    nh = cfg.n_heads
    hd = d // nh
    dff = int(cfg.slstm_ff * d)
    keys = jax.random.split(key, 4)
    p, a = {}, {}
    p["wx"], a["wx"] = dense_init(keys[0], d, 4 * d, ("embed_fsdp", "ff"),
                                  dtype=dtype)
    p["r"] = (jax.random.normal(keys[1], (nh, hd, 4 * hd)) /
              jnp.sqrt(hd)).astype(dtype)
    a["r"] = ("heads", None, None)
    p["b"] = jnp.concatenate([
        jnp.zeros((2 * d,)), jnp.linspace(3.0, 6.0, d), jnp.zeros((d,)),
    ]).astype(dtype)
    a["b"] = (None,)
    p["norm"], a["norm"] = rmsnorm_init(d, dtype)
    p["ff_i"], a["ff_i"] = dense_init(keys[2], d, dff, ("embed_fsdp", "ff"),
                                      dtype=dtype)
    p["ff_o"], a["ff_o"] = dense_init(keys[3], dff, d, ("ff", "embed_fsdp"),
                                      dtype=dtype)
    return p, a


def _slstm_cell(p, xt, state, nh, hd):
    """xt (B, 4d) preactivations from W x; state dict of (B,H,hd)."""
    c, n, hprev, m = state["c"], state["n"], state["h"], state["m"]
    b = xt.shape[0]
    rec = jnp.einsum("bhk,hkj->bhj", hprev, p["r"])          # (B,H,4hd)
    d = nh * hd
    pre = xt.reshape(b, nh, 4 * hd) + rec + p["b"].reshape(nh * 4, hd) \
        .reshape(4, nh, hd).transpose(1, 0, 2).reshape(nh, 4 * hd)
    z = jnp.tanh(pre[..., :hd].astype(jnp.float32))
    li = pre[..., hd:2 * hd].astype(jnp.float32)
    lf = jax.nn.log_sigmoid(pre[..., 2 * hd:3 * hd].astype(jnp.float32))
    o = jax.nn.sigmoid(pre[..., 3 * hd:].astype(jnp.float32))
    m_new = jnp.maximum(lf + m, li)
    fw = jnp.exp(lf + m - m_new)
    iw = jnp.exp(li - m_new)
    c = fw * c + iw * z
    n = fw * n + iw
    h = o * c / jnp.maximum(n, 1.0)
    return {"c": c, "n": n, "h": h, "m": m_new}, h


def slstm_forward(p, x, *, d, cfg: XLSTMConfig, return_state=False):
    b, s, _ = x.shape
    nh = cfg.n_heads
    hd = d // nh
    xs = dense(p["wx"], x)                                   # (B,S,4d)
    state = slstm_state(b, d, cfg)

    def step(st, xt):
        st, h = _slstm_cell(p, xt, st, nh, hd)
        return st, h

    state, hs = jax.lax.scan(step, state, jnp.moveaxis(xs, 0, 1))
    y = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)
    y = rmsnorm(p["norm"], y)
    y = dense(p["ff_o"], jax.nn.gelu(dense(p["ff_i"], y), approximate=True))
    if return_state:
        return y, state
    return y


def slstm_state(batch, d, cfg: XLSTMConfig):
    nh = cfg.n_heads
    hd = d // nh
    z = lambda: jnp.zeros((batch, nh, hd), jnp.float32)
    return {"c": z(), "n": z(), "h": z(),
            "m": jnp.full((batch, nh, hd), -1e30, jnp.float32)}


def slstm_state_axes():
    ax = ("batch", "heads", None)
    return {"c": ax, "n": ax, "h": ax, "m": ax}


def slstm_decode(p, x, state, *, d, cfg: XLSTMConfig):
    b = x.shape[0]
    nh = cfg.n_heads
    hd = d // nh
    xt = dense(p["wx"], x)[:, 0, :]
    state, h = _slstm_cell(p, xt, state, nh, hd)
    y = h.reshape(b, 1, d).astype(x.dtype)
    y = rmsnorm(p["norm"], y)
    y = dense(p["ff_o"], jax.nn.gelu(dense(p["ff_i"], y), approximate=True))
    return y, state

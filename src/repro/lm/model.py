"""LM substrate assembly: pattern-based blocks under scan-over-layers.

A model is ``reps`` repetitions of a block ``pattern`` (e.g. gemma3 =
8 x (5 local + 1 global); xlstm = 6 x (7 mLSTM + 1 sLSTM)); layer params are
stacked over reps and the layer stack runs under ``lax.scan`` (+remat), so
HLO size is depth-independent — essential for the 40-cell dry-run matrix.
"shared" pattern positions (zamba2's shared attention block) read weights
from outside the scan (true cross-rep sharing); their *caches* stay per-rep.

Decode caches are pytrees stacked over reps and threaded through the scan as
xs/ys.  The LM head loss is vocab-sharded + sequence-chunked (never
materializes (tokens, vocab) logits; docs/DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.dist.logical import lc
from repro.lm import attention as attn
from repro.lm import moe as moe_lib
from repro.lm import ssm as ssm_lib
from repro.lm import xlstm as xlstm_lib
from repro.lm.layers import dense, embed_init, mlp, mlp_init, rmsnorm, \
    rmsnorm_init, softcap

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class MoEOpts:
    num_experts: int
    top_k: int
    d_ff_expert: int
    shared_ff: int = 0
    router_act: str = "softmax"
    capacity_factor: float = 1.25
    dispatch: str = "global_sort"   # global_sort | grouped_a2a (§Perf)


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    d_model: int
    n_layers: int                       # decoder layers (== reps*len(pattern))
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    pattern: tuple = ("attn",)
    rope_theta: float = 10_000.0
    window: int | None = None           # for "local" blocks
    attn_softcap: float | None = None
    final_softcap: float | None = None
    qk_norm: bool = False
    attn_scale: float | None = None
    post_norm: bool = False             # gemma2 sandwich
    mlp_kind: str = "swiglu"
    moe: MoEOpts | None = None
    ssm: ssm_lib.SSMConfig | None = None
    xlstm: xlstm_lib.XLSTMConfig | None = None
    encoder_layers: int = 0             # >0 => encoder-decoder
    emb_scale: bool = False
    tie_embeddings: bool = True
    vocab_pad_to: int = 256
    param_dtype: str = "float32"
    dtype: str = "bfloat16"             # activation/compute dtype
    frontend: str = "tokens"            # tokens | embeddings (audio stub)
    long_context_ok: bool = False       # sub-quadratic: run long_500k
    remat: bool = True
    loss_chunk: int = 1024
    # scan_layers=False unrolls the layer stack in Python — used by the
    # dry-run's metric compiles (XLA cost analysis counts while-loop bodies
    # once, so costs are fitted from unrolled 1-rep/2-rep compiles).
    scan_layers: bool = True
    flash_chunk: int = 1024             # KV-chunked attention block size
    unroll_inner: bool = False          # unroll inner chunk scans (metrics)

    @property
    def hd(self):
        return self.head_dim or self.d_model // self.n_heads

    @property
    def reps(self):
        assert self.n_layers % len(self.pattern) == 0, \
            f"{self.name}: {self.n_layers} layers % pattern {len(self.pattern)}"
        return self.n_layers // len(self.pattern)

    @property
    def padded_vocab(self):
        m = self.vocab_pad_to
        return (self.vocab + m - 1) // m * m

    @property
    def act_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def attn_kwargs(self, kind):
        return dict(n_heads=self.n_heads, n_kv=self.n_kv_heads,
                    head_dim=self.hd, rope_theta=self.rope_theta,
                    window=self.window
                    if kind in ("local", "shared_attn") else None,
                    cap=self.attn_softcap, qk_norm=self.qk_norm,
                    scale=self.attn_scale, flash_chunk=self.flash_chunk,
                    unroll=self.unroll_inner)


ATTN_KINDS = ("attn", "local", "moe", "shared_attn", "xattn", "enc_attn")
SHARED_KINDS = ("shared_attn",)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _block_init(key, cfg: LMConfig, kind: str):
    d, dt = cfg.d_model, cfg.pdtype
    p, a = {}, {}
    keys = jax.random.split(key, 8)
    p["ln1"], a["ln1"] = rmsnorm_init(d, dt)
    if kind in ("attn", "local", "moe", "shared_attn", "enc_attn", "xattn"):
        p["attn"], a["attn"] = attn.attn_init(
            keys[0], d, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
            qk_norm=cfg.qk_norm, dtype=dt)
        p["ln2"], a["ln2"] = rmsnorm_init(d, dt)
        if cfg.post_norm:
            p["pn1"], a["pn1"] = rmsnorm_init(d, dt)
            p["pn2"], a["pn2"] = rmsnorm_init(d, dt)
        if kind == "xattn":
            p["xattn"], a["xattn"] = attn.attn_init(
                keys[1], d, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                qk_norm=False, dtype=dt)
            p["lnx"], a["lnx"] = rmsnorm_init(d, dt)
        if kind == "moe":
            p["ffn"], a["ffn"] = moe_lib.moe_init(
                keys[2], d, cfg.moe.d_ff_expert, cfg.moe.num_experts,
                kind=cfg.mlp_kind, shared_ff=cfg.moe.shared_ff, dtype=dt)
        else:
            p["ffn"], a["ffn"] = mlp_init(keys[2], d, cfg.d_ff,
                                          cfg.mlp_kind, dtype=dt)
    elif kind == "mamba":
        p["mix"], a["mix"] = ssm_lib.mamba2_init(keys[0], d, cfg.ssm, dt)
    elif kind == "mlstm":
        p["mix"], a["mix"] = xlstm_lib.mlstm_init(keys[0], d, cfg.xlstm, dt)
    elif kind == "slstm":
        p["mix"], a["mix"] = xlstm_lib.slstm_init(keys[0], d, cfg.xlstm, dt)
    else:
        raise ValueError(kind)
    return p, a


def _stack_init(key, cfg: LMConfig, pattern, reps):
    """Stacked per-rep params for non-shared positions + single shared."""
    scanned_p, scanned_a, shared_p, shared_a = {}, {}, {}, {}
    for i, kind in enumerate(pattern):
        name = f"b{i}_{kind}"
        if kind in SHARED_KINDS:
            shared_p[name], shared_a[name] = _block_init(
                jax.random.fold_in(key, 1000 + i), cfg, kind)
            continue

        def one(k):
            return _block_init(k, cfg, kind)[0]

        ks = jax.random.split(jax.random.fold_in(key, i), reps)
        scanned_p[name] = jax.vmap(one)(ks)
        _, axes = _block_init(jax.random.fold_in(key, i), cfg, kind)
        # Stacked params gain a leading "layers" dim; a None axes-leaf means
        # fully replicated, which stays valid at any rank.
        scanned_a[name] = jax.tree.map(
            lambda ax: None if ax is None else ("layers",) + tuple(ax),
            axes, is_leaf=_is_axes_leaf)
    return scanned_p, scanned_a, shared_p, shared_a


def _is_axes_leaf(x):
    return x is None or (isinstance(x, tuple) and all(
        y is None or isinstance(y, str) for y in x))


def init(key, cfg: LMConfig):
    """Returns (params, logical-axes tree)."""
    p, a = {}, {}
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p["emb"], a["emb"] = embed_init(k1, cfg.padded_vocab, cfg.d_model,
                                    cfg.pdtype)
    p["scan"], a["scan"], p["shared"], a["shared"] = _stack_init(
        k2, cfg, cfg.pattern, cfg.reps)
    p["lnf"], a["lnf"] = rmsnorm_init(cfg.d_model, cfg.pdtype)
    if not cfg.tie_embeddings:
        p["head"], a["head"] = embed_init(k3, cfg.padded_vocab, cfg.d_model,
                                          cfg.pdtype)
    if cfg.encoder_layers:
        enc_cfg = dataclasses.replace(cfg, pattern=("enc_attn",),
                                      n_layers=cfg.encoder_layers)
        (p["enc_scan"], a["enc_scan"], _, _) = _stack_init(
            k4, enc_cfg, ("enc_attn",), cfg.encoder_layers)
        p["enc_lnf"], a["enc_lnf"] = rmsnorm_init(cfg.d_model, cfg.pdtype)
    return p, a


# ---------------------------------------------------------------------------
# forward blocks (full-sequence path)
# ---------------------------------------------------------------------------

def _block_fwd(p, cfg: LMConfig, kind, x, enc_out=None):
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "local", "moe", "shared_attn", "enc_attn", "xattn"):
        h = rmsnorm(p["ln1"], x)
        y = attn.full_attention(p["attn"], h, causal=kind != "enc_attn",
                                **cfg.attn_kwargs(kind))
        if cfg.post_norm:
            y = rmsnorm(p["pn1"], y)
        x = x + y
        if kind == "xattn":
            h = rmsnorm(p["lnx"], x)
            y = attn.full_attention(p["xattn"], h, x_kv=enc_out,
                                    causal=False, use_rope=False,
                                    **cfg.attn_kwargs(kind))
            x = x + y
        h = rmsnorm(p["ln2"], x)
        if kind == "moe":
            y, mo = moe_lib.moe_apply(
                p["ffn"], h, n_experts=cfg.moe.num_experts,
                top_k=cfg.moe.top_k, kind=cfg.mlp_kind,
                capacity_factor=cfg.moe.capacity_factor,
                router_act=cfg.moe.router_act,
                shared=cfg.moe.shared_ff > 0,
                dispatch=cfg.moe.dispatch)
            aux = aux + 0.01 * mo["aux_lb"] + 0.001 * mo["aux_z"]
        else:
            y = mlp(p["ffn"], h, cfg.mlp_kind)
        if cfg.post_norm:
            y = rmsnorm(p["pn2"], y)
        x = x + y
    elif kind == "mamba":
        x = x + ssm_lib.mamba2_forward(p["mix"], rmsnorm(p["ln1"], x),
                                       d=cfg.d_model, cfg=cfg.ssm)
    elif kind == "mlstm":
        x = x + xlstm_lib.mlstm_forward(p["mix"], rmsnorm(p["ln1"], x),
                                        d=cfg.d_model, cfg=cfg.xlstm)
    elif kind == "slstm":
        x = x + xlstm_lib.slstm_forward(p["mix"], rmsnorm(p["ln1"], x),
                                        d=cfg.d_model, cfg=cfg.xlstm)
    else:
        raise ValueError(kind)
    return lc(x, "batch", None, "embed"), aux


def _run_stack(params, cfg: LMConfig, x, pattern, scan_key="scan",
               enc_out=None):
    shared = params.get("shared", {})

    def rep_body(carry, rep_params):
        x, aux = carry
        for i, kind in enumerate(pattern):
            name = f"b{i}_{kind}"
            p = shared[name] if kind in SHARED_KINDS else rep_params[name]
            x, a = _block_fwd(p, cfg, kind, x, enc_out)
            aux = aux + a
        return (x, aux), None

    body = jax.checkpoint(rep_body) if cfg.remat else rep_body
    carry = (x, jnp.zeros((), jnp.float32))
    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(body, carry, params[scan_key])
    else:
        reps = jax.tree.leaves(params[scan_key])[0].shape[0]
        for r in range(reps):
            rp = jax.tree.map(lambda t: t[r], params[scan_key])
            carry, _ = body(carry, rp)
        x, aux = carry
    return x, aux


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg: LMConfig, tokens):
    x = params["emb"]["w"][tokens].astype(cfg.act_dtype)
    if cfg.emb_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.act_dtype)
    return lc(x, "batch", None, "embed")


def encode(params, cfg: LMConfig, frames):
    """Encoder for enc-dec models; frames (B, S, D) from the frontend stub."""
    x = lc(frames.astype(cfg.act_dtype), "batch", None, "embed")
    x, _ = _run_stack(params, cfg, x, ("enc_attn",), scan_key="enc_scan")
    return rmsnorm(params["enc_lnf"], x)


def forward(params, cfg: LMConfig, tokens=None, frames=None, dec_tokens=None):
    """Full-sequence forward -> (hidden (B,S,D), aux)."""
    enc_out = None
    if cfg.encoder_layers:
        enc_out = encode(params, cfg, frames)
        x = embed_tokens(params, cfg, dec_tokens)
    elif cfg.frontend == "embeddings":
        x = lc(frames.astype(cfg.act_dtype), "batch", None, "embed")
    else:
        x = embed_tokens(params, cfg, tokens)
    x, aux = _run_stack(params, cfg, x, cfg.pattern, enc_out=enc_out)
    return rmsnorm(params["lnf"], x), aux


def logits_for(params, cfg: LMConfig, hidden):
    """(B, T, D) -> (B, T, padded_vocab) — small T only (decode)."""
    w = params["head" if not cfg.tie_embeddings else "emb"]["w"]
    logits = hidden @ w.astype(hidden.dtype).T
    logits = softcap(logits, cfg.final_softcap)
    neg = jnp.asarray(-1e30, logits.dtype)
    mask = jnp.arange(cfg.padded_vocab) < cfg.vocab
    return jnp.where(mask, logits, neg)


def lm_loss(params, cfg: LMConfig, hidden, targets, loss_mask=None):
    """Sequence-chunked, vocab-sharded cross entropy (no (T,V) tensor)."""
    b, s, d = hidden.shape
    c = min(cfg.loss_chunk, s)
    assert s % c == 0
    nc = s // c
    w = params["head" if not cfg.tie_embeddings else "emb"]["w"]
    mask = (jnp.arange(cfg.padded_vocab) < cfg.vocab)
    if loss_mask is None:
        loss_mask = jnp.ones((b, s), bool)

    hc = jnp.moveaxis(hidden.reshape(b, nc, c, d), 1, 0)
    tc = jnp.moveaxis(targets.reshape(b, nc, c), 1, 0)
    mc = jnp.moveaxis(loss_mask.reshape(b, nc, c), 1, 0)

    def chunk(carry, inp):
        h, t, m = inp
        logits = (h @ w.astype(h.dtype).T).astype(jnp.float32)
        logits = softcap(logits, cfg.final_softcap)
        logits = jnp.where(mask, logits, -1e30)
        logits = lc(logits, "batch", None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        nll = jnp.where(m, lse - ll, 0.0)
        return (carry[0] + nll.sum(), carry[1] + m.sum()), None

    (tot, cnt), _ = jax.lax.scan(chunk, (jnp.zeros(()), jnp.zeros(())),
                                 (hc, tc, mc))
    return tot / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# serving: prefill + decode (cache pytrees stacked over reps)
# ---------------------------------------------------------------------------

def init_cache(params, cfg: LMConfig, batch, max_len, enc_len=None):
    """Cache skeleton: dict per pattern position, stacked over reps."""
    reps = cfg.reps
    cache = {}
    for i, kind in enumerate(cfg.pattern):
        name = f"b{i}_{kind}"
        if kind in ("attn", "local", "moe", "shared_attn"):
            one = attn.init_cache(batch, max_len, cfg.n_kv_heads, cfg.hd,
                                  cfg.act_dtype,
                                  window=cfg.window if kind in
                                  ("local", "shared_attn") else None)
        elif kind == "xattn":
            one = {
                "self": attn.init_cache(batch, max_len, cfg.n_kv_heads,
                                        cfg.hd, cfg.act_dtype),
                "cross": attn.init_cache(batch, enc_len, cfg.n_kv_heads,
                                         cfg.hd, cfg.act_dtype),
            }
        elif kind == "mamba":
            one = ssm_lib.init_state(batch, cfg.d_model, cfg.ssm,
                                     cfg.act_dtype)
        elif kind == "mlstm":
            one = xlstm_lib.mlstm_state(batch, cfg.d_model, cfg.xlstm)
        elif kind == "slstm":
            one = xlstm_lib.slstm_state(batch, cfg.d_model, cfg.xlstm)
        else:
            raise ValueError(kind)
        cache[name] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (reps,) + x.shape), one)
    return cache


def cache_axes(cfg: LMConfig):
    out = {}
    for i, kind in enumerate(cfg.pattern):
        name = f"b{i}_{kind}"
        if kind in ("attn", "local", "moe", "shared_attn"):
            one = attn.cache_axes()
        elif kind == "xattn":
            one = {"self": attn.cache_axes(), "cross": attn.cache_axes()}
        elif kind == "mamba":
            one = ssm_lib.state_axes()
        elif kind == "mlstm":
            one = xlstm_lib.mlstm_state_axes()
        elif kind == "slstm":
            one = xlstm_lib.slstm_state_axes()
        out[name] = jax.tree.map(
            lambda ax: ("layers",) + tuple(ax),
            one, is_leaf=lambda x: isinstance(x, tuple) and all(
                y is None or isinstance(y, str) for y in x))
    return out


def _block_decode(p, cfg: LMConfig, kind, x, cache, pos, enc_out=None):
    if kind in ("attn", "local", "moe", "shared_attn"):
        h = rmsnorm(p["ln1"], x)
        y, cache = attn.decode_attention(p["attn"], h, cache, pos,
                                         **cfg.attn_kwargs(kind))
        if cfg.post_norm:
            y = rmsnorm(p["pn1"], y)
        x = x + y
        h = rmsnorm(p["ln2"], x)
        if kind == "moe":
            y, _ = moe_lib.moe_apply(
                p["ffn"], h, n_experts=cfg.moe.num_experts,
                top_k=cfg.moe.top_k, kind=cfg.mlp_kind,
                capacity_factor=cfg.moe.capacity_factor,
                router_act=cfg.moe.router_act,
                shared=cfg.moe.shared_ff > 0, no_drop=True)
        else:
            y = mlp(p["ffn"], h, cfg.mlp_kind)
        if cfg.post_norm:
            y = rmsnorm(p["pn2"], y)
        x = x + y
    elif kind == "xattn":
        h = rmsnorm(p["ln1"], x)
        y, new_self = attn.decode_attention(p["attn"], h, cache["self"],
                                            pos, **cfg.attn_kwargs(kind))
        x = x + y
        h = rmsnorm(p["lnx"], x)
        y, _ = attn.decode_attention(p["xattn"], h, cache["cross"], pos,
                                     cross=True, use_rope=False,
                                     **cfg.attn_kwargs(kind))
        x = x + y
        h = rmsnorm(p["ln2"], x)
        x = x + mlp(p["ffn"], h, cfg.mlp_kind)
        cache = {"self": new_self, "cross": cache["cross"]}
    elif kind == "mamba":
        y, cache = ssm_lib.mamba2_decode(p["mix"], rmsnorm(p["ln1"], x),
                                         cache, d=cfg.d_model, cfg=cfg.ssm)
        x = x + y
    elif kind == "mlstm":
        y, cache = xlstm_lib.mlstm_decode(p["mix"], rmsnorm(p["ln1"], x),
                                          cache, d=cfg.d_model,
                                          cfg=cfg.xlstm)
        x = x + y
    elif kind == "slstm":
        y, cache = xlstm_lib.slstm_decode(p["mix"], rmsnorm(p["ln1"], x),
                                          cache, d=cfg.d_model,
                                          cfg=cfg.xlstm)
        x = x + y
    else:
        raise ValueError(kind)
    return x, cache


def decode_step(params, cfg: LMConfig, token, cache, pos):
    """One decode step: token (B,1) (or (B,1,D) embeddings), position pos.
    Returns (logits (B,1,V), new cache)."""
    if cfg.frontend == "embeddings" and token.ndim == 3:
        x = token.astype(cfg.act_dtype)
    else:
        x = embed_tokens(params, cfg, token)
    shared = params.get("shared", {})

    def rep_body(x, xs):
        rep_params, rep_cache = xs
        new_cache = {}
        for i, kind in enumerate(cfg.pattern):
            name = f"b{i}_{kind}"
            p = shared[name] if kind in SHARED_KINDS else rep_params[name]
            x, new_cache[name] = _block_decode(p, cfg, kind, x,
                                               rep_cache[name], pos)
        return x, new_cache

    if cfg.scan_layers:
        x, new_cache = jax.lax.scan(rep_body, x, (params["scan"], cache))
    else:
        reps = jax.tree.leaves(params["scan"])[0].shape[0]
        caches = []
        for r in range(reps):
            xs_r = jax.tree.map(lambda t: t[r], (params["scan"], cache))
            x, c = rep_body(x, xs_r)
            caches.append(c)
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
    h = rmsnorm(params["lnf"], x)
    return logits_for(params, cfg, h), new_cache


def prefill(params, cfg: LMConfig, tokens=None, frames=None,
            dec_tokens=None, max_len=None):
    """Prefill: full forward that also fills the cache.

    For simplicity and HLO-size parity we run the full-sequence path and
    recompute per-layer KV into the cache via a second pass of projections
    only where needed; attention caches are filled by re-running the stack
    in cache-filling mode (scan ys).
    """
    b = (tokens if tokens is not None else frames).shape[0]
    s = (dec_tokens if dec_tokens is not None else
         tokens if tokens is not None else frames).shape[1]
    max_len = max_len or s
    enc_out = None
    if cfg.encoder_layers:
        enc_out = encode(params, cfg, frames)
        x = embed_tokens(params, cfg, dec_tokens)
    elif cfg.frontend == "embeddings":
        x = lc(frames.astype(cfg.act_dtype), "batch", None, "embed")
    else:
        x = embed_tokens(params, cfg, tokens)

    shared = params.get("shared", {})

    def rep_body(x, rep_params):
        caches = {}
        for i, kind in enumerate(cfg.pattern):
            name = f"b{i}_{kind}"
            p = shared[name] if kind in SHARED_KINDS else rep_params[name]
            x, caches[name] = _block_prefill(p, cfg, kind, x, max_len,
                                             enc_out)
        return x, caches

    body = jax.checkpoint(rep_body) if cfg.remat else rep_body
    if cfg.scan_layers:
        x, cache = jax.lax.scan(body, x, params["scan"])
    else:
        reps = jax.tree.leaves(params["scan"])[0].shape[0]
        caches = []
        for r in range(reps):
            rp = jax.tree.map(lambda t: t[r], params["scan"])
            x, c = body(x, rp)
            caches.append(c)
        cache = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
    h = rmsnorm(params["lnf"], x)
    last = logits_for(params, cfg, h[:, -1:, :])
    return last, cache


def _block_prefill(p, cfg: LMConfig, kind, x, max_len, enc_out=None):
    if kind in ("attn", "local", "moe", "shared_attn"):
        h = rmsnorm(p["ln1"], x)
        y, kv = attn.prefill_attention(p["attn"], h, max_len=max_len,
                                       **cfg.attn_kwargs(kind))
        if cfg.post_norm:
            y = rmsnorm(p["pn1"], y)
        x = x + y
        h = rmsnorm(p["ln2"], x)
        if kind == "moe":
            y, _ = moe_lib.moe_apply(
                p["ffn"], h, n_experts=cfg.moe.num_experts,
                top_k=cfg.moe.top_k, kind=cfg.mlp_kind,
                capacity_factor=cfg.moe.capacity_factor,
                router_act=cfg.moe.router_act,
                shared=cfg.moe.shared_ff > 0,
                dispatch=cfg.moe.dispatch)
        else:
            y = mlp(p["ffn"], h, cfg.mlp_kind)
        if cfg.post_norm:
            y = rmsnorm(p["pn2"], y)
        return x + y, kv
    if kind == "xattn":
        h = rmsnorm(p["ln1"], x)
        y, kv = attn.prefill_attention(p["attn"], h, max_len=max_len,
                                       **cfg.attn_kwargs(kind))
        x = x + y
        h = rmsnorm(p["lnx"], x)
        y, xkv = attn.full_attention(p["xattn"], h, x_kv=enc_out,
                                     causal=False, use_rope=False,
                                     return_kv=True, **cfg.attn_kwargs(kind))
        x = x + y
        h = rmsnorm(p["ln2"], x)
        x = x + mlp(p["ffn"], h, cfg.mlp_kind)
        return x, {"self": kv, "cross": {"k": xkv[0], "v": xkv[1]}}
    if kind == "mamba":
        y, st = ssm_lib.mamba2_forward(p["mix"], rmsnorm(p["ln1"], x),
                                       d=cfg.d_model, cfg=cfg.ssm,
                                       return_state=True)
        return x + y, st
    if kind == "mlstm":
        y, st = xlstm_lib.mlstm_forward(p["mix"], rmsnorm(p["ln1"], x),
                                        d=cfg.d_model, cfg=cfg.xlstm,
                                        return_state=True)
        return x + y, st
    if kind == "slstm":
        y, st = xlstm_lib.slstm_forward(p["mix"], rmsnorm(p["ln1"], x),
                                        d=cfg.d_model, cfg=cfg.xlstm,
                                        return_state=True)
        return x + y, st
    raise ValueError(kind)

"""Base layers for the LM substrate: params are nested dicts; every init
returns (params, logical-axes tree) so dist/logical.py can derive shardings
without name-pattern guessing."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.logical import lc

Array = jax.Array


def dense_init(key, din, dout, axes=("embed_fsdp", "ff"), scale=None,
               dtype=jnp.float32):
    scale = (2.0 / (din + dout)) ** 0.5 if scale is None else scale
    w = (jax.random.normal(key, (din, dout)) * scale).astype(dtype)
    return {"w": w}, {"w": axes}


def dense(p, x):
    # Params may be f32 while activations run bf16: cast weights into the
    # activation dtype so matmuls stay in compute precision.
    return x @ p["w"].astype(x.dtype)


def rmsnorm_init(d, dtype=jnp.float32):
    return {"g": jnp.ones((d,), dtype)}, {"g": None}


def rmsnorm(p, x, eps=1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps).astype(x.dtype)
    return out * p["g"].astype(x.dtype)


def embed_init(key, vocab, d, dtype=jnp.float32):
    w = (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)
    return {"w": w}, {"w": ("vocab", "embed_fsdp")}


def rope(x: Array, positions: Array, theta: float):
    """x (..., S, H, hd), positions (..., S) -> rotated x."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def softcap(x, cap):
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


# --- MLP variants -----------------------------------------------------------

def mlp_init(key, d, d_ff, kind, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        p, a = {}, {}
        p["wi"], a["wi"] = dense_init(k1, d, d_ff, ("embed_fsdp", "ff"),
                                      dtype=dtype)
        p["wg"], a["wg"] = dense_init(k2, d, d_ff, ("embed_fsdp", "ff"),
                                      dtype=dtype)
        p["wo"], a["wo"] = dense_init(k3, d_ff, d, ("ff", "embed_fsdp"),
                                      dtype=dtype)
        return p, a
    if kind == "relu2":
        p, a = {}, {}
        p["wi"], a["wi"] = dense_init(k1, d, d_ff, ("embed_fsdp", "ff"),
                                      dtype=dtype)
        p["wo"], a["wo"] = dense_init(k3, d_ff, d, ("ff", "embed_fsdp"),
                                      dtype=dtype)
        return p, a
    raise ValueError(kind)


def mlp(p, x, kind):
    if kind == "swiglu":
        h = jax.nn.silu(dense(p["wg"], x)) * dense(p["wi"], x)
    elif kind == "geglu":
        h = jax.nn.gelu(dense(p["wg"], x), approximate=True) * dense(p["wi"], x)
    elif kind == "relu2":
        h = jnp.square(jax.nn.relu(dense(p["wi"], x)))
    else:
        raise ValueError(kind)
    h = lc(h, "batch", None, "ff")
    return dense(p["wo"], h)

"""GQA attention (full/prefill and decode-with-cache paths).

Sharding (baseline v0, docs/DESIGN.md §6): *sequence-parallel* attention — the
query sequence is sharded over the ``model`` mesh axis for train/prefill and
the KV-cache sequence for decode.  This is uniform over every head count
(9-head smollm and 64-head chameleon alike), at the cost of per-layer KV
all-gathers; head-sharded variants are a §Perf exploration.

GQA never materializes repeated KV: queries are reshaped to
(B, S, KV, group, hd) and contracted against (B, S, KV, hd) directly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.logical import lc
from repro.lm.layers import dense, dense_init, rmsnorm, rmsnorm_init, rope, \
    softcap

Array = jax.Array
NEG = -2.0e38


def attn_init(key, d, n_heads, n_kv, head_dim, *, qk_norm=False,
              dtype=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p, a = {}, {}
    p["wq"], a["wq"] = dense_init(k1, d, n_heads * head_dim,
                                  ("embed_fsdp", "ff"), dtype=dtype)
    p["wk"], a["wk"] = dense_init(k2, d, n_kv * head_dim,
                                  ("embed_fsdp", "ff"), dtype=dtype)
    p["wv"], a["wv"] = dense_init(k3, d, n_kv * head_dim,
                                  ("embed_fsdp", "ff"), dtype=dtype)
    p["wo"], a["wo"] = dense_init(k4, n_heads * head_dim, d,
                                  ("ff", "embed_fsdp"), dtype=dtype)
    if qk_norm:
        p["qn"], a["qn"] = rmsnorm_init(head_dim, dtype)
        p["kn"], a["kn"] = rmsnorm_init(head_dim, dtype)
    return p, a


def _project_qkv(p, xq, xkv, n_heads, n_kv, head_dim, *, positions_q,
                 positions_kv, rope_theta, qk_norm, use_rope=True):
    b, sq, _ = xq.shape
    sk = xkv.shape[1]
    q = dense(p["wq"], xq).reshape(b, sq, n_heads, head_dim)
    k = dense(p["wk"], xkv).reshape(b, sk, n_kv, head_dim)
    v = dense(p["wv"], xkv).reshape(b, sk, n_kv, head_dim)
    if qk_norm:
        q = rmsnorm(p["qn"], q)
        k = rmsnorm(p["kn"], k)
    if use_rope:
        q = rope(q, positions_q, rope_theta)
        k = rope(k, positions_kv, rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, *, scale, cap):
    """q (B,Sq,H,hd), k/v (B,Sk,KV,hd), mask (B,1,1,Sq,Sk) or None."""
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k) * scale
    scores = softcap(scores, cap)
    if mask is not None:
        scores = jnp.where(mask, scores, NEG)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(b, sq, h, hd)


def _sdpa_flash(q, k, v, *, scale, cap, causal, window, chunk, unroll=False):
    """Online-softmax attention over KV chunks: O(Sq*chunk) score memory
    instead of O(Sq*Sk); the chunk scan body is rematerialized so the
    backward pass stays chunked too (flash-attention structure)."""
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, hd).astype(jnp.float32)
    iq = jax.lax.broadcasted_iota(jnp.int32, (sq, 1), 0)
    nc = -(-sk // chunk)
    pad = nc * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = jnp.moveaxis(k.reshape(b, nc, chunk, kv, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, nc, chunk, kv, hd), 1, 0)
    k0s = jnp.arange(nc, dtype=jnp.int32) * chunk

    def body(carry, xs):
        m, l, acc = carry
        kcb, vcb, k0 = xs
        s = jnp.einsum("bqkgh,bskh->bkgqs", qg,
                       kcb.astype(jnp.float32)) * scale
        s = softcap(s, cap)
        col = k0 + jax.lax.broadcasted_iota(jnp.int32, (1, chunk), 1)
        ok = col < sk
        if causal:
            ok = ok & (col <= iq)
        if window is not None:
            ok = ok & (col > iq - window)
        s = jnp.where(ok[None, None, None, :, :], s, NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskh->bkgqh", p, vcb.astype(jnp.float32))
        return (m_new, l, acc), None

    # Carry shardings must be pinned: loop-carried values default to
    # replicated, which re-materializes the full (…, Sq) row state on every
    # model shard (25 GB/device at prefill_32k before this constraint).
    row = lambda t: lc(t, "batch", "heads", None, "seq_shard")
    init = (row(jnp.full((b, kv, g, sq), NEG, jnp.float32)),
            row(jnp.zeros((b, kv, g, sq), jnp.float32)),
            lc(jnp.zeros((b, kv, g, sq, hd), jnp.float32),
               "batch", "heads", None, "seq_shard", None))

    def body_c(carry, xs):
        (m, l, acc), ys = body(carry, xs)
        return (row(m), row(l),
                lc(acc, "batch", "heads", None, "seq_shard", None)), ys

    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body_c), init,
                                  (kc, vc, k0s),
                                  unroll=nc if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, -2, 1)  # (b, sq, kv, g, hd)
    return out.reshape(b, sq, h, hd).astype(q.dtype)


FLASH_THRESHOLD = 4096  # KV lengths above this use the chunked kernel


def _dispatch_sdpa(q, k, v, *, scale, cap, causal, window, flash_chunk,
                   unroll):
    sq, sk = q.shape[1], k.shape[1]
    if sk > FLASH_THRESHOLD:
        return _sdpa_flash(q, k, v, scale=scale, cap=cap, causal=causal,
                           window=window, chunk=flash_chunk, unroll=unroll)
    iq = jnp.arange(sq)[:, None]
    ik = jnp.arange(sk)[None, :]
    m = jnp.ones((sq, sk), bool)
    if causal:
        m &= ik <= iq
    if window is not None:
        m &= ik > iq - window
    return _sdpa(q, k, v, m[None, None, None, :, :], scale=scale, cap=cap)


def full_attention(p, x, *, n_heads, n_kv, head_dim, rope_theta,
                   causal=True, window=None, cap=None, qk_norm=False,
                   scale=None, x_kv=None, use_rope=True,
                   return_kv=False, flash_chunk=1024, unroll=False):
    """Train/prefill attention. x (B,S,D). Cross-attn when x_kv is given."""
    b, s, _ = x.shape
    xkv = x if x_kv is None else x_kv
    sk = xkv.shape[1]
    pos_q = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    pos_k = jnp.broadcast_to(jnp.arange(sk)[None], (b, sk))
    q, k, v = _project_qkv(p, x, xkv, n_heads, n_kv, head_dim,
                           positions_q=pos_q, positions_kv=pos_k,
                           rope_theta=rope_theta, qk_norm=qk_norm,
                           use_rope=use_rope and x_kv is None)
    # v0: shard the query sequence; gather KV (see module docstring).
    q = lc(q, "batch", "seq_shard", "heads", None)
    k = lc(k, "batch", None, "heads", None)
    v = lc(v, "batch", None, "heads", None)
    scale = (head_dim ** -0.5) if scale is None else scale
    out = _dispatch_sdpa(q, k, v, scale=scale, cap=cap,
                         causal=causal and x_kv is None,
                         window=window if x_kv is None else None,
                         flash_chunk=flash_chunk, unroll=unroll)
    out = lc(out, "batch", "seq_shard", "heads", None)
    y = dense(p["wo"], out.reshape(b, s, n_heads * head_dim))
    y = lc(y, "batch", None, "embed")
    if return_kv:
        return y, (k, v)
    return y


def cache_len(max_len, window):
    """Local-attention layers keep a rolling window-sized cache (serving
    memory: a 1024-window gemma3 layer needs 1024 slots, not 32k)."""
    return max_len if window is None else min(window, max_len)


def init_cache(batch, max_len, n_kv, head_dim, dtype=jnp.float32,
               window=None):
    w = cache_len(max_len, window)
    return {
        "k": jnp.zeros((batch, w, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, w, n_kv, head_dim), dtype),
    }


def cache_axes():
    return {"k": ("batch", "kv_seq", "heads", None),
            "v": ("batch", "kv_seq", "heads", None)}


def prefill_attention(p, x, *, n_heads, n_kv, head_dim, rope_theta,
                      max_len, window=None, cap=None, qk_norm=False,
                      scale=None, use_rope=True, flash_chunk=1024,
                      unroll=False):
    """Prefill: full (chunked) attention + cache filled to max_len (or the
    rolling window for local layers: slot of abs position a is a % W)."""
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    q, k, v = _project_qkv(p, x, x, n_heads, n_kv, head_dim,
                           positions_q=pos, positions_kv=pos,
                           rope_theta=rope_theta, qk_norm=qk_norm,
                           use_rope=use_rope)
    q = lc(q, "batch", "seq_shard", "heads", None)
    scale_ = (head_dim ** -0.5) if scale is None else scale
    out = _dispatch_sdpa(q, k, v, scale=scale_, cap=cap, causal=True,
                         window=window, flash_chunk=flash_chunk,
                         unroll=unroll)
    y = dense(p["wo"], out.reshape(b, s, n_heads * head_dim))
    w = cache_len(max_len, window)
    if w < s:  # keep the last w keys at slots (abs % w)
        slots = (jnp.arange(s - w, s) % w)
        kw = jnp.zeros((b, w) + k.shape[2:], k.dtype).at[:, slots].set(
            k[:, s - w:])
        vw = jnp.zeros((b, w) + v.shape[2:], v.dtype).at[:, slots].set(
            v[:, s - w:])
        cache = {"k": kw, "v": vw}
    else:
        cache = init_cache(b, max_len, n_kv, head_dim, x.dtype, window)
        cache = {
            "k": jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0)),
            "v": jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0)),
        }
    cache = {kk: lc(vv, "batch", "kv_seq", "heads", None)
             for kk, vv in cache.items()}
    return y, cache


def decode_attention(p, x, cache, pos, *, n_heads, n_kv, head_dim,
                     rope_theta, window=None, cap=None, qk_norm=False,
                     scale=None, cross=False, use_rope=True,
                     flash_chunk=None, unroll=False):
    """One-token decode. x (B,1,D); cache KV seq sharded over `model`;
    softmax over the sharded axis becomes small all-reduces under GSPMD.

    Local layers use a rolling cache (slot = pos % W); keys are stored
    already-rotated at absolute positions so RoPE needs no re-rotation.
    cross=True: cache holds (already-projected) encoder KV; no update."""
    b = x.shape[0]
    clen = cache["k"].shape[1]
    posb = jnp.broadcast_to(pos.reshape(-1, 1), (b, 1))
    q, k_new, v_new = _project_qkv(
        p, x, x, n_heads, n_kv, head_dim, positions_q=posb,
        positions_kv=posb, rope_theta=rope_theta, qk_norm=qk_norm,
        use_rope=use_rope and not cross)
    windowed = window is not None and clen == window
    slot = (pos % clen) if windowed else pos
    if not cross:
        cache = {
            "k": lc(jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1),
                "batch", "kv_seq", "heads", None),
            "v": lc(jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1),
                "batch", "kv_seq", "heads", None),
        }
    k, v = cache["k"], cache["v"]
    ik = jnp.arange(clen)[None, :]
    if cross:
        m = jnp.ones((1, clen), bool)
    elif windowed:
        m = (ik <= pos)  # rolling buffer holds exactly the last W abs pos
    else:
        m = ik <= pos
        if window is not None:
            m &= ik > pos - window
    mask = m[:, None, None, None, :]
    scale_ = (head_dim ** -0.5) if scale is None else scale
    out = _sdpa(q, k, v, mask, scale=scale_, cap=cap)
    y = dense(p["wo"], out.reshape(b, 1, n_heads * head_dim))
    return y, cache

"""Mixture-of-Experts with sort-based (dropping) dispatch.

Tokens are routed top-k, assignments sorted by expert, truncated to a static
per-expert capacity, and run through a grouped (E, C, d) x (E, d, f) einsum
— so expert FLOPs stay ~T*k*cf*d*f instead of the T*E*d of one-hot dispatch
einsums.  Expert weights are sharded over the ``experts`` logical axis (EP);
the token gather/scatter across data shards is GSPMD's all-to-all.

Router aux losses: switch-style load balancing + router z-loss.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.dist.logical import lc
from repro.lm.layers import dense, dense_init, mlp, mlp_init

Array = jax.Array


def moe_init(key, d, d_ff, n_experts, *, kind="swiglu", shared_ff=0,
             dtype=jnp.float32):
    keys = jax.random.split(key, 5)
    scale = (2.0 / (d + d_ff)) ** 0.5
    p, a = {}, {}
    p["router"], a["router"] = dense_init(keys[0], d, n_experts,
                                          ("embed_fsdp", None), dtype=dtype)

    def ew(k, din, dout):
        w = (jax.random.normal(k, (n_experts, din, dout)) * scale).astype(dtype)
        return w

    p["wi"] = ew(keys[1], d, d_ff)
    a["wi"] = ("experts", None, "ff")
    if kind in ("swiglu", "geglu"):
        p["wg"] = ew(keys[2], d, d_ff)
        a["wg"] = ("experts", None, "ff")
    p["wo"] = ew(keys[3], d_ff, d)
    a["wo"] = ("experts", "ff", None)
    if shared_ff:
        p["shared"], a["shared"] = mlp_init(keys[4], d, shared_ff, kind,
                                            dtype=dtype)
    return p, a


def _expert_ffn(p, xb, kind):
    """Grouped expert matmuls on a (..., C, d) buffer batched over E."""
    wdt = lambda w: w.astype(xb.dtype)
    hi = jnp.einsum("e...cd,edf->e...cf", xb, wdt(p["wi"]))
    if kind == "swiglu":
        h = jax.nn.silu(jnp.einsum("e...cd,edf->e...cf", xb,
                                   wdt(p["wg"]))) * hi
    elif kind == "geglu":
        h = jax.nn.gelu(jnp.einsum("e...cd,edf->e...cf", xb, wdt(p["wg"])),
                        approximate=True) * hi
    else:  # relu2
        h = jnp.square(jax.nn.relu(hi))
    h = lc(h, "experts", *([None] * (h.ndim - 2)), "ff")
    return jnp.einsum("e...cf,efd->e...cd", h, wdt(p["wo"]))


def _route(logits, top_k, router_act):
    if router_act == "softmax":
        probs = jax.nn.softmax(logits, axis=-1)
        gate, eidx = jax.lax.top_k(probs, top_k)
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    else:  # sigmoid (llama4-style)
        gate, eidx = jax.lax.top_k(logits, top_k)
        gate = jax.nn.sigmoid(gate)
        probs = jax.nn.softmax(logits, axis=-1)
    return gate, eidx, probs


def _sort_dispatch(flat_e, t, top_k, n_experts, cap):
    """Sort assignments by expert; returns (order, slot (T*k,), keep)."""
    order = jnp.argsort(flat_e)
    se = flat_e[order]
    counts = jnp.bincount(flat_e, length=n_experts)
    cum = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                           jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(t * top_k, dtype=jnp.int32) - cum[se].astype(jnp.int32)
    keep = rank < cap
    slot = jnp.where(keep, se * cap + rank, n_experts * cap)
    return order, se, slot, keep


def moe_apply(p, x, *, n_experts, top_k, kind="swiglu",
              capacity_factor=1.25, router_act="softmax",
              shared: bool = False, no_drop: bool = False,
              dispatch: str = "global_sort"):
    """x (B, S, D) -> (y (B, S, D), aux dict).

    ``no_drop=True`` sets capacity to T*k (serving/decode: token counts are
    small and dropping tokens at decode corrupts generation).
    ``dispatch="grouped_a2a"`` routes per data-shard group and moves tokens
    with two all-to-alls (sharded transpose) instead of global gathers —
    the §Perf optimization for collective-bound MoE cells."""
    if dispatch == "grouped_a2a" and not no_drop:
        return _moe_apply_grouped(p, x, n_experts=n_experts, top_k=top_k,
                                  kind=kind,
                                  capacity_factor=capacity_factor,
                                  router_act=router_act, shared=shared)
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    logits = dense(p["router"], xf).astype(jnp.float32)     # (T, E)
    if router_act == "softmax":
        probs = jax.nn.softmax(logits, axis=-1)
        gate, eidx = jax.lax.top_k(probs, top_k)
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    else:  # sigmoid (llama4-style): independent expert scores
        gate, eidx = jax.lax.top_k(logits, top_k)
        gate = jax.nn.sigmoid(gate)
        probs = jax.nn.softmax(logits, axis=-1)

    # Aux losses (switch LB + z-loss).
    me = jnp.mean(probs, axis=0)                            # (E,)
    onehot = jax.nn.one_hot(eidx[:, 0], n_experts, dtype=jnp.float32)
    ce = jnp.mean(onehot, axis=0)
    aux_lb = n_experts * jnp.sum(me * ce)
    aux_z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))

    if no_drop:
        cap = t * top_k
    else:
        cap = max(int(math.ceil(t * top_k * capacity_factor / n_experts)), 1)

    flat_e = eidx.reshape(-1)                               # (T*k,)
    flat_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), top_k)
    flat_g = gate.reshape(-1).astype(x.dtype)
    order = jnp.argsort(flat_e)                             # stable
    se = flat_e[order]
    stok = flat_t[order]
    sg = flat_g[order]
    counts = jnp.bincount(flat_e, length=n_experts)
    cum = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                           jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(t * top_k, dtype=jnp.int32) - cum[se].astype(jnp.int32)
    keep = rank < cap
    slot = jnp.where(keep, se * cap + rank, n_experts * cap)  # sentinel

    # Token buffer (E, C, D); sentinel row stays zero.  GSPMD shards gather
    # *outputs* like their indices, so the index tensors are reshaped to
    # their logical layout and constrained BEFORE the gathers — otherwise
    # the (E*C, D) dispatch rows materialize replicated (25 GB/device at
    # granite prefill_32k).
    xpad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], 0)
    buf_tok = jnp.full((n_experts * cap + 1,), t, jnp.int32).at[slot].set(
        stok, mode="drop")
    buf_tok2 = lc(buf_tok[:-1].reshape(n_experts, cap),
                  "experts", "expert_cap")
    xb = xpad[buf_tok2]
    xb = lc(xb, "experts", "expert_cap", None)  # cap rows sharded (TP)

    wdt = lambda w: w.astype(xb.dtype)
    hi = jnp.einsum("ecd,edf->ecf", xb, wdt(p["wi"]))
    if kind == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xb, wdt(p["wg"]))) * hi
    elif kind == "geglu":
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xb, wdt(p["wg"])),
                        approximate=True) * hi
    else:  # relu2
        h = jnp.square(jax.nn.relu(hi))
    h = lc(h, "experts", None, "ff")  # hidden stays TP on ff
    yb = jnp.einsum("ecf,efd->ecd", h, wdt(p["wo"]))
    yb = lc(yb, "experts", "expert_cap", None)

    # Return path: gate-weighted scatter-add straight from the (E, C)
    # buffer (never flattening sharded dims — GSPMD replicates merged-dim
    # shardings).  Duplicate token rows (top-k) accumulate.
    g_buf = jnp.zeros((n_experts * cap + 1,), x.dtype).at[slot].set(
        jnp.where(keep, sg, 0), mode="drop")
    g2 = lc(g_buf[:-1].reshape(n_experts, cap), "experts", "expert_cap")
    y = jnp.zeros((t + 1, d), yb.dtype).at[buf_tok2].add(
        yb * g2[..., None], mode="drop")[:t]
    y = lc(y.reshape(b, s, d), "batch", None, None).reshape(t, d)

    if shared and "shared" in p:
        y = y + mlp(p["shared"], x, kind).reshape(t, d)
    frac_dropped = 1.0 - jnp.sum(keep) / (t * top_k)
    return y.reshape(b, s, d), {"aux_lb": aux_lb, "aux_z": aux_z,
                                "frac_dropped": frac_dropped}


def _moe_apply_grouped(p, x, *, n_experts, top_k, kind, capacity_factor,
                       router_act, shared):
    """Grouped all-to-all dispatch (§Perf variant).

    Tokens are routed/sorted *within their data-shard group*; the dispatch
    buffer (G, E, C_g, d) is then transposed to (E, G, C_g, d) with the
    expert dim sharded — a sharded transpose that GSPMD lowers to an
    all-to-all, moving only ~top_k*cf token payloads per chip instead of
    the global-sort path's replicated gathers.  Capacity is per-group
    (C_g = ceil(T_g*k*cf/E)); aux losses are computed globally.
    """
    from repro.dist import logical as _logical

    g = _logical.axis_size("batch")
    b, s, d = x.shape
    if g <= 1 or b % g:
        return moe_apply(p, x, n_experts=n_experts, top_k=top_k, kind=kind,
                         capacity_factor=capacity_factor,
                         router_act=router_act, shared=shared,
                         dispatch="global_sort")
    t = b * s
    tg = t // g
    xg = lc(x.reshape(g, tg, d), "batch", None, None)
    logits = dense(p["router"], xg).astype(jnp.float32)     # (G, Tg, E)
    gate, eidx, probs = _route(logits, top_k, router_act)

    pf = probs.reshape(t, n_experts)
    me = jnp.mean(pf, axis=0)
    ce = jnp.mean(jax.nn.one_hot(eidx.reshape(t, top_k)[:, 0], n_experts,
                                 dtype=jnp.float32), axis=0)
    aux_lb = n_experts * jnp.sum(me * ce)
    aux_z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))

    cap = max(int(math.ceil(tg * top_k * capacity_factor / n_experts)), 1)

    def group_dispatch(eidx_g, gate_g):
        flat_e = eidx_g.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(tg, dtype=jnp.int32), top_k)
        order, se, slot, keep = _sort_dispatch(flat_e, tg, top_k,
                                               n_experts, cap)
        stok = flat_t[order]
        sg = gate_g.reshape(-1)[order]
        buf_tok = jnp.full((n_experts * cap + 1,), tg,
                           jnp.int32).at[slot].set(stok, mode="drop")
        g_buf = jnp.zeros((n_experts * cap + 1,),
                          gate_g.dtype).at[slot].set(
            jnp.where(keep, sg, 0), mode="drop")
        return (buf_tok[:-1].reshape(n_experts, cap),
                g_buf[:-1].reshape(n_experts, cap),
                jnp.sum(keep))

    buf_tok, g_buf, kept = jax.vmap(group_dispatch)(
        eidx, gate.astype(x.dtype))                         # (G, E, cap)
    buf_tok = lc(buf_tok, "batch", None, "expert_cap")

    xpad = jnp.concatenate([xg, jnp.zeros((g, 1, d), xg.dtype)], axis=1)
    xb = jax.vmap(lambda xp, bt: xp[bt])(xpad, buf_tok)     # (G, E, cap, d)
    xb = lc(xb, "batch", None, "expert_cap", None)

    # Sharded transpose == all-to-all (G<->E).
    xe = lc(jnp.swapaxes(xb, 0, 1), "experts", None, "expert_cap", None)
    ye = _expert_ffn(p, xe, kind)                           # (E, G, cap, d)
    ye = lc(ye, "experts", None, "expert_cap", None)
    yg = lc(jnp.swapaxes(ye, 0, 1), "batch", None, "expert_cap", None)

    def group_combine(y_g, bt, gg):
        out = jnp.zeros((tg + 1, d), y_g.dtype)
        return out.at[bt].add(y_g * gg[..., None], mode="drop")[:tg]

    y = jax.vmap(group_combine)(yg, buf_tok, g_buf)         # (G, Tg, d)
    y = lc(y, "batch", None, None).reshape(b, s, d)
    if shared and "shared" in p:
        y = y + mlp(p["shared"], x, kind)
    frac_dropped = 1.0 - jnp.sum(kept) / (t * top_k)
    return y, {"aux_lb": aux_lb, "aux_z": aux_z,
               "frac_dropped": frac_dropped}

"""Mamba2 (SSD) block — chunked parallel train/prefill, recurrent decode.

Chunkwise state-space duality: within a chunk (length L) the output is an
attention-like masked product; across chunks a small scan carries the
(H, P, N) state.  The (L, L) decay matrices are materialized per head like
the reference implementation; heads are sharded over the ``model`` axis
(``ssm_heads`` logical axis) so the per-device footprint stays bounded.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.dist.logical import lc
from repro.lm.layers import dense, dense_init, rmsnorm, rmsnorm_init

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    expand: int = 2
    headdim: int = 64
    conv_kernel: int = 4
    chunk: int = 128

    def d_inner(self, d):
        return self.expand * d

    def n_heads(self, d):
        return self.d_inner(d) // self.headdim


def mamba2_init(key, d, cfg: SSMConfig, dtype=jnp.float32):
    di = cfg.d_inner(d)
    h = cfg.n_heads(d)
    n = cfg.d_state
    conv_dim = di + 2 * n
    keys = jax.random.split(key, 6)
    p, a = {}, {}
    # in_proj -> [z(di), x(di), B(n), C(n), dt(h)]
    p["in"], a["in"] = dense_init(keys[0], d, 2 * di + 2 * n + h,
                                  ("embed_fsdp", "ff"), dtype=dtype)
    p["conv_w"] = (jax.random.normal(keys[1], (cfg.conv_kernel, conv_dim))
                   * 0.1).astype(dtype)
    a["conv_w"] = (None, "ff")
    p["conv_b"] = jnp.zeros((conv_dim,), dtype)
    a["conv_b"] = ("ff",)
    p["A_log"] = jnp.log(jnp.linspace(1.0, 16.0, h)).astype(dtype)
    a["A_log"] = ("ssm_heads",)
    dt0 = jnp.exp(jax.random.uniform(keys[2], (h,))
                  * (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001))
    p["dt_bias"] = (dt0 + jnp.log(-jnp.expm1(-dt0))).astype(dtype)
    a["dt_bias"] = ("ssm_heads",)
    p["D"] = jnp.ones((h,), dtype)
    a["D"] = ("ssm_heads",)
    p["norm"], a["norm"] = rmsnorm_init(di, dtype)
    p["out"], a["out"] = dense_init(keys[3], di, d, ("ff", "embed_fsdp"),
                                    dtype=dtype)
    return p, a


def _split_proj(p, x, d, cfg: SSMConfig):
    di = cfg.d_inner(d)
    h = cfg.n_heads(d)
    n = cfg.d_state
    zxbcdt = dense(p["in"], x)
    z = zxbcdt[..., :di]
    xin = zxbcdt[..., di:2 * di]
    bm = zxbcdt[..., 2 * di:2 * di + n]
    cm = zxbcdt[..., 2 * di + n:2 * di + 2 * n]
    dt = zxbcdt[..., 2 * di + 2 * n:]
    return z, xin, bm, cm, dt


def _conv_full(p, u, k):
    """Causal depthwise conv over (B, S, C)."""
    pad = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + u.shape[1], :] * p["conv_w"][i]
              for i in range(k))
    return out + p["conv_b"]


def mamba2_forward(p, x, *, d, cfg: SSMConfig, return_state=False):
    """x (B, S, D) -> y (B, S, D) [, state for decode continuation]."""
    b, s, _ = x.shape
    di, h, n, L = cfg.d_inner(d), cfg.n_heads(d), cfg.d_state, cfg.chunk
    ph = cfg.headdim
    z, xin, bm, cm, dt = _split_proj(p, x, d, cfg)
    conv_in = jnp.concatenate([xin, bm, cm], -1)
    conv_out = jax.nn.silu(_conv_full(p, conv_in, cfg.conv_kernel))
    xin = conv_out[..., :di]
    bm = conv_out[..., di:di + n]
    cm = conv_out[..., di + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                 # (H,)
    la = dt * A                                                  # log-decay

    # Pad to a chunk multiple; padded steps are identity (a=1, dt=0) so the
    # carried state and real outputs are unaffected.
    pad = (-s) % L
    if pad:
        xin = jnp.pad(xin, ((0, 0), (0, pad), (0, 0)))
        bm = jnp.pad(bm, ((0, 0), (0, pad), (0, 0)))
        cm = jnp.pad(cm, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        la = jnp.pad(la, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    nc = sp // L
    xh = xin.reshape(b, nc, L, h, ph)
    xh = lc(xh, "batch", None, None, "ssm_heads", None)
    dtc = dt.reshape(b, nc, L, h)
    lac = jnp.cumsum(la.reshape(b, nc, L, h), axis=2)            # (B,nc,L,H)
    bmc = bm.reshape(b, nc, L, n).astype(jnp.float32)
    cmc = cm.reshape(b, nc, L, n).astype(jnp.float32)

    # Intra-chunk (attention-like, causal):
    cb = jnp.einsum("bcln,bcsn->bcls", cmc, bmc)                 # (B,nc,L,L)
    decay = jnp.exp(lac[:, :, :, None, :] - lac[:, :, None, :, :])
    causal = jnp.tril(jnp.ones((L, L), bool))[None, None, :, :, None]
    m = jnp.where(causal, cb[..., None] * decay, 0.0)            # (B,nc,L,L,H)
    m = m * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bclsh,bcshp->bclhp", m, xh.astype(jnp.float32))

    # Chunk states + inter-chunk scan:
    dec_out = jnp.exp(lac[:, :, -1:, :] - lac)                   # (B,nc,L,H)
    sloc = jnp.einsum("bclh,bcln,bclhp->bchnp",
                      dec_out * dtc, bmc, xh.astype(jnp.float32))
    chunk_decay = jnp.exp(lac[:, :, -1, :])                      # (B,nc,H)

    def scanner(carry, inp):
        s_loc, cd = inp
        new = carry * cd[:, :, None, None] + s_loc
        return new, carry

    init = jnp.zeros((b, h, n, ph), jnp.float32)
    final, s_prev = jax.lax.scan(
        scanner, init,
        (jnp.moveaxis(sloc, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    s_prev = jnp.moveaxis(s_prev, 0, 1)                          # (B,nc,H,N,P)
    y_inter = jnp.einsum("bcln,bclh,bchnp->bclhp",
                         cmc, jnp.exp(lac), s_prev)

    y = (y_intra + y_inter).reshape(b, sp, h, ph)[:, :s]
    y = y + xin[:, :s].reshape(b, s, h, ph).astype(jnp.float32) * \
        p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, s, di).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = dense(p["out"], y)
    if not return_state:
        return out
    conv_tail = jnp.swapaxes(conv_in[:, -(cfg.conv_kernel - 1):, :], 1, 2)
    return out, {"ssd": final, "conv": conv_tail,
                 }


def init_state(batch, d, cfg: SSMConfig, dtype=jnp.float32):
    di, h, n = cfg.d_inner(d), cfg.n_heads(d), cfg.d_state
    return {
        "ssd": jnp.zeros((batch, h, n, cfg.headdim), jnp.float32),
        "conv": jnp.zeros((batch, di + 2 * n, cfg.conv_kernel - 1), dtype),
    }


def state_axes():
    return {"ssd": ("batch", "ssm_heads", None, None),
            "conv": ("batch", "ff", None)}


def mamba2_decode(p, x, state, *, d, cfg: SSMConfig):
    """One-token step. x (B, 1, D)."""
    b = x.shape[0]
    di, h, n, ph = cfg.d_inner(d), cfg.n_heads(d), cfg.d_state, cfg.headdim
    z, xin, bm, cm, dt = _split_proj(p, x, d, cfg)
    u = jnp.concatenate([xin, bm, cm], -1)[:, 0, :]              # (B, convdim)
    hist = jnp.concatenate([state["conv"],
                            u[:, :, None].astype(state["conv"].dtype)], -1)
    conv = jnp.einsum("bck,kc->bc", hist, p["conv_w"]) + p["conv_b"]
    conv = jax.nn.silu(conv)
    xin = conv[:, :di].reshape(b, h, ph).astype(jnp.float32)
    bmv = conv[:, di:di + n].astype(jnp.float32)
    cmv = conv[:, di + n:].astype(jnp.float32)
    dtv = jax.nn.softplus(dt[:, 0, :].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dtv * A)                                         # (B,H)
    ssd = state["ssd"] * a[:, :, None, None] + jnp.einsum(
        "bh,bn,bhp->bhnp", dtv, bmv, xin)
    y = jnp.einsum("bn,bhnp->bhp", cmv, ssd)
    y = y + xin * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = dense(p["out"], y)
    return out, {"ssd": ssd, "conv": hist[:, :, 1:]}

"""repro.scene — streaming large-scale scene inference (DESIGN.md §10).

Tile -> halo -> stitch: a 100k–1M-point scene is cut into DFT-contiguous
fractal tiles (``tiler``), each tile (plus a halo ring of border context)
streams through the bucketed, plan-cached serving engine (``executor`` on
top of ``repro.serve``), and per-point segmentation logits scatter back to
scene order under the owner-tile rule (``stitch``) — no O(n²) op is ever
materialized.  ``examples/segment_scene.py`` is the demo;
``benchmarks/scene_bench.py`` tracks points/s and peak-memory scaling.
"""
from repro.scene.executor import SceneConfig, SceneEngine
from repro.scene.stitch import owner_of, stitch, stitch_tile
from repro.scene.tiler import ScenePlan, Tile, tile_scene

__all__ = [
    "SceneConfig", "SceneEngine", "ScenePlan", "Tile", "owner_of",
    "stitch", "stitch_tile", "tile_scene",
]

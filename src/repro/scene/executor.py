"""Scene executor: stream tiles through the bucketed serving engine (§10).

One ``SceneEngine`` owns a ``serve.ServeEngine`` and drives it with tiles
instead of user requests: each tile cloud (owned points + halo ring) is
admitted to its minimal shape bucket, packed into fixed microbatches, and
executed by the per-(bucket, impl) cached forward — the scene path buys
all of §9 (one compile per bucket, ``mesh="auto"`` sharding microbatches
across devices) for free.  Two scene-specific twists:

* every tile submission carries ``dim0 = tile.depth % 3`` so the cached
  partition plan re-derives the tile's *global* subtree (§10 exactness);
* results are drained after every submit (``step()``) and stitched by the
  owner-tile rule, so peak live memory is one microbatch of tile tensors
  plus the (n, num_classes) output — never an O(n²) or all-tiles
  footprint.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro import serve
from repro.core import fractal
from repro.scene import stitch as _stitch
from repro.scene import tiler as _tiler


@dataclasses.dataclass(frozen=True)
class SceneConfig:
    """Scene-inference knobs: tiling + the serve/model knobs they feed."""

    # Tiling (tiler.py).
    tile_points: int = 4096        # coarse partition threshold (tile size)
    halo: float = 0.1              # halo radius (0 = off; exactness mode)
    halo_window: int | None = None     # DFT candidate window (2*tile_points)
    max_halo_points: int | None = None  # halo cap (tile_points // 4)
    # Serving (serve/engine.py).
    buckets: tuple | None = None   # shape ladder; default derived from the
                                   # max tile+halo size
    microbatch: int = 4            # tiles per dispatch (mesh data axis)
    mesh: str = "none"             # none | auto (shard tiles over devices)
    model_axis: int = 2
    # Model (models/pnn.py).
    variant: str = "pointnet2"
    num_classes: int = 6
    th: int = 256                  # model block threshold (<< tile_points)
    strategy: str = "fractal"
    point_ops: str = "bppo"        # bppo | global (global: no plan/dim0)
    impl: str | None = None        # xla | pallas | None ($REPRO_POINT_IMPL)
    leaf_chunk: int | None = None
    stages: tuple | None = None    # override model stages (e.g. the
    fp_widths: tuple | None = None  # single-SA-stage exactness config, §10)

    def max_tile_cloud(self) -> int:
        """Largest admissible tile cloud: owned + halo cap."""
        cap = (self.tile_points // 4 if self.max_halo_points is None
               else self.max_halo_points)
        return self.tile_points + (cap if self.halo > 0 else 0)


class SceneEngine:
    """Tile -> halo -> serve -> stitch for one model (DESIGN.md §10)."""

    def __init__(self, cfg: SceneConfig, params=None, mesh=None, seed=0):
        if cfg.tile_points <= cfg.th:
            raise ValueError(
                f"tile_points ({cfg.tile_points}) must exceed the model "
                f"block threshold th ({cfg.th}): tiles are re-partitioned "
                f"into th-point blocks")
        self.cfg = cfg
        top = cfg.max_tile_cloud()
        buckets = cfg.buckets or (max(top // 2, 1), top)
        self.serve_cfg = serve.ServeConfig(
            buckets=buckets, microbatch=cfg.microbatch,
            # The executor drives dispatch itself (step after submit,
            # flush at end), so the deadline never gates a tile.
            max_wait_s=3600.0, variant=cfg.variant, task="seg",
            num_classes=cfg.num_classes, th=cfg.th, strategy=cfg.strategy,
            point_ops=cfg.point_ops, impl=cfg.impl,
            leaf_chunk=cfg.leaf_chunk, mesh=cfg.mesh,
            model_axis=cfg.model_axis, stages=cfg.stages,
            fp_widths=cfg.fp_widths)
        self.engine = serve.ServeEngine(self.serve_cfg, params=params,
                                        mesh=mesh, seed=seed)
        self.params = self.engine.params
        self.impl = self.engine.impl

    def warm(self, buckets=None) -> dict:
        """Compile the per-bucket executables up front (see §9)."""
        return self.engine.warm(buckets)

    def plan(self, coords) -> _tiler.ScenePlan:
        """Tile one scene (no inference) — inspection / reuse."""
        return _tiler.tile_scene(
            coords, tile_points=self.cfg.tile_points, halo=self.cfg.halo,
            halo_window=self.cfg.halo_window,
            max_halo_points=self.cfg.max_halo_points,
            strategy=self.cfg.strategy)

    def infer(self, coords, plan: _tiler.ScenePlan | None = None):
        """Segment one (n, 3) scene; returns ((n, num_classes) logits,
        ScenePlan).

        Tiles stream through the serve queue: completed microbatches are
        drained after every submit, so at no point do more than one
        microbatch of padded tile tensors plus the output live at once.
        """
        coords = np.asarray(coords, np.float32)
        if plan is None:
            plan = self.plan(coords)
        if plan.overflowed:
            # Fail fast with the actionable error, not an opaque
            # bucket-ladder ValueError mid-stream: an oversize coarse leaf
            # means an unsplittable (duplicate-heavy) region deeper than
            # the depth cap.
            raise fractal.FractalOverflowError(
                f"coarse tiling overflowed: a tile kept more than "
                f"tile_points={self.cfg.tile_points} points at the depth "
                f"cap (n={plan.n}) — the scene has an unsplittable "
                f"duplicate-heavy region; raise tile_points or dedupe")
        # Stitch-on-drain: each completed tile scatters straight into the
        # output, so the only n-proportional live arrays really are the
        # input and this buffer (no all-tiles results dict).
        logits = np.zeros((plan.n, self.cfg.num_classes), np.float32)
        tiles = {t.tid: t for t in plan.tiles}
        rid_tid: dict[int, int] = {}
        seen = 0

        def drain(rids):
            nonlocal seen
            for rid in rids:
                tile = tiles[rid_tid.pop(rid)]
                seen += _stitch.stitch_tile(logits, tile,
                                            self.engine.take(rid))

        for tile in plan.tiles:
            rid = self.engine.submit(coords[tile.indices], dim0=tile.dim0)
            rid_tid[rid] = tile.tid
            drain(self.engine.step())
        drain(self.engine.flush())
        if seen != plan.n:
            raise ValueError(f"tiles own {seen} points, scene has {plan.n}")
        return logits, plan

    def stats(self) -> dict:
        """Serve-layer stats (latencies, plan cache) for the tile stream."""
        return self.engine.stats()

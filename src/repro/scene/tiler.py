"""Scene tiler: coarse fractal pre-partition + halo rings (DESIGN.md §10).

A room-scale cloud (100k–1M points) is cut into tiles by the *same*
level-synchronous engine that builds the per-model block structure
(``core/fractal.py``), run once at a coarse threshold ``tile_points``.
Two properties of that tree do all the work:

* **tiles are DFT-contiguous** — every coarse leaf is one contiguous slice
  of the sorted arrays, so a tile is a zero-copy range, and its spatial
  neighbors sit in nearby slices (§3).  Halo candidates therefore come
  from a bounded DFT window around the tile's range instead of an O(n)
  all-tiles scan — the reason halos are cheap at 1M points.
* **tiles are exact subtrees** — the fractal split of a node depends only
  on the points inside it, never on ``th``, so the coarse tree is a
  prefix of any finer tree over the same cloud.  Re-partitioning a tile's
  points with the model's own ``th`` and the tile's split-dimension phase
  (``dim0 = depth % 3``) reproduces the global subtree exactly, which is
  what makes tile-wise inference consistent with a whole-scene forward
  (the §10 exactness contract, tested in tests/test_scene.py).

The tiler is host-side glue: the partition itself is one jitted call; the
per-tile index bookkeeping is numpy over O(tile + window) slices.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import numpy as np

from repro.core import fractal


@dataclasses.dataclass(frozen=True)
class Tile:
    """One dispatchable unit of a scene: owned points + halo context."""

    tid: int               # compact tile id (coarse-DFT order)
    owned: np.ndarray      # (n_owned,) original indices, coarse-DFT order
    halo: np.ndarray       # (n_halo,) original indices (context only:
                           # present for neighbor search, never stitched)
    depth: int             # coarse-tree depth of the tile node
    lo: np.ndarray         # (3,) bbox min of the owned points
    hi: np.ndarray         # (3,) bbox max

    @property
    def dim0(self) -> int:
        """Split-phase for re-partitioning: a node at depth d splits on
        dimension d % 3, so the tile's local level 0 must too."""
        return self.depth % 3

    @property
    def n_owned(self) -> int:
        return len(self.owned)

    @property
    def n(self) -> int:
        return len(self.owned) + len(self.halo)

    @property
    def indices(self) -> np.ndarray:
        """Tile-cloud gather indices: owned first (coarse-DFT order), halo
        appended — the stitcher relies on this layout."""
        return np.concatenate([self.owned, self.halo])


@dataclasses.dataclass(frozen=True)
class ScenePlan:
    """The full tiling of one scene (every point owned by exactly one tile)."""

    n: int
    tile_points: int
    halo: float
    strategy: str
    tiles: tuple            # tuple[Tile, ...], coarse-DFT order
    overflowed: bool        # coarse tree hit its depth cap (oversize tiles)

    @property
    def num_tiles(self) -> int:
        return len(self.tiles)

    @property
    def halo_points(self) -> int:
        return sum(len(t.halo) for t in self.tiles)

    @property
    def max_tile_n(self) -> int:
        return max((t.n for t in self.tiles), default=0)


@functools.lru_cache(maxsize=None)
def _partition_fn(tile_points: int, strategy: str, depth: int | None):
    return jax.jit(lambda c: fractal.partition(
        c, th=tile_points, strategy=strategy, depth=depth))


def _bbox_dist(pts: np.ndarray, lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
    """Euclidean distance of each point to an axis-aligned box (0 inside)."""
    d = np.maximum(np.maximum(lo - pts, pts - hi), 0.0)
    return np.sqrt((d * d).sum(-1))


def tile_scene(coords, *, tile_points: int, halo: float = 0.0,
               halo_window: int | None = None,
               max_halo_points: int | None = None,
               strategy: str = fractal.FRACTAL,
               depth: int | None = None) -> ScenePlan:
    """Cut one (n, 3) cloud into <= ``tile_points``-point tiles + halos.

    ``halo`` is a radius: points of *other* tiles within ``halo`` of a
    tile's bounding box join that tile's cloud as context (so border
    neighborhoods are as populated as an untiled run), but their outputs
    are discarded at stitch time — the owner-tile rule.  Candidates are
    drawn from a ``halo_window``-point DFT window on each side of the
    tile's range (default ``2 * tile_points``; DFT adjacency ≈ spatial
    adjacency, §3) and capped at the ``max_halo_points`` nearest (default
    ``tile_points // 4``).  ``halo=0`` disables halos, which is also the
    exactness mode (§10).
    """
    if tile_points <= 0:
        raise ValueError(f"tile_points must be positive, got {tile_points}")
    if halo < 0:
        raise ValueError(f"halo must be >= 0, got {halo}")
    coords = np.asarray(coords, np.float32)
    n = coords.shape[0]
    part = _partition_fn(tile_points, strategy, depth)(coords)

    # One host pull each; everything after is numpy slices.
    perm = np.asarray(part.perm)
    sorted_pts = np.asarray(part.coords)
    valid = np.asarray(part.valid)
    is_leaf = np.asarray(part.is_leaf)
    starts = np.asarray(part.leaf_start)
    rsizes = np.asarray(part.leaf_rsize)
    vsizes = np.asarray(part.leaf_vsize)
    depths = np.asarray(part.leaf_depth)
    overflowed = bool(part.overflowed)

    W = (2 * tile_points) if halo_window is None else int(halo_window)
    cap = (tile_points // 4) if max_halo_points is None else int(
        max_halo_points)

    tiles = []
    for i in np.nonzero(is_leaf)[0]:
        s, r, v, d = int(starts[i]), int(rsizes[i]), int(vsizes[i]), \
            int(depths[i])
        if v == 0:
            continue  # invalid-only / empty leaf: nothing to own
        owned_pos = np.arange(s, s + v)
        tpts = sorted_pts[owned_pos]
        lo, hi = tpts.min(0), tpts.max(0)
        halo_ids = np.empty((0,), perm.dtype)
        if halo > 0 and cap > 0:
            cand = np.concatenate([np.arange(max(0, s - W), s),
                                   np.arange(s + r, min(n, s + r + W))])
            cand = cand[valid[cand]]
            if len(cand):
                dist = _bbox_dist(sorted_pts[cand], lo, hi)
                near = dist <= halo
                cand, dist = cand[near], dist[near]
                if len(cand) > cap:
                    cand = cand[np.argsort(dist, kind="stable")[:cap]]
                    cand.sort()  # keep halo in DFT order (determinism)
                halo_ids = perm[cand]
        tiles.append(Tile(tid=len(tiles), owned=perm[owned_pos],
                          halo=halo_ids, depth=d, lo=lo, hi=hi))
    return ScenePlan(n=n, tile_points=tile_points, halo=halo,
                     strategy=strategy, tiles=tuple(tiles),
                     overflowed=overflowed)

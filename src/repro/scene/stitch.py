"""Stitching: scatter per-tile outputs back to scene point order (§10).

The owner-tile rule: every scene point is *owned* by exactly one tile
(tiles are the leaves of one coarse partition, which tile [0, n)); any
other tile that sees the point saw it as *halo context* and its output
row for that point is discarded.  Because the executor submits each tile
cloud owned-first (``Tile.indices``), stitching is a single scatter of
each output's owned prefix — no overlap resolution pass, no atomics, and
the result is deterministic regardless of tile completion order.
"""
from __future__ import annotations

import numpy as np

from repro.scene.tiler import ScenePlan


def stitch_tile(out: np.ndarray, tile, rows) -> int:
    """Scatter one tile's owned-prefix rows into ``out``; returns the
    number of points written.  The single place the owner-tile rule is
    applied — the streaming executor calls it per drained tile so only
    the scene-sized output stays live."""
    rows = np.asarray(rows)
    if rows.shape[0] != tile.n:
        raise ValueError(
            f"tile {tile.tid}: expected {tile.n} rows "
            f"({tile.n_owned} owned + {len(tile.halo)} halo), "
            f"got {rows.shape[0]}")
    out[tile.owned] = rows[:tile.n_owned]
    return tile.n_owned


def stitch(plan: ScenePlan, outputs: dict, width: int,
           dtype=np.float32) -> np.ndarray:
    """Assemble per-tile per-point rows into one (n, width) scene array.

    ``outputs[tid]`` is the (tile.n, width) result for tile ``tid``, rows
    in ``Tile.indices`` order (owned first, halo appended).  Halo rows are
    dropped; owned rows scatter to their original scene positions.
    """
    out = np.zeros((plan.n, width), dtype)
    seen = sum(stitch_tile(out, tile, outputs[tile.tid])
               for tile in plan.tiles)
    if seen != plan.n:
        raise ValueError(f"tiles own {seen} points, scene has {plan.n}")
    return out


def owner_of(plan: ScenePlan) -> np.ndarray:
    """(n,) tile id owning each scene point (diagnostics / tests)."""
    owner = np.full((plan.n,), -1, np.int32)
    for tile in plan.tiles:
        owner[tile.owned] = tile.tid
    return owner

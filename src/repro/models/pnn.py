"""Point-based neural networks (paper Table I workloads) in functional JAX.

Backbone = Abstraction stages (point ops + feature MLPs) and, for
segmentation, Propagation stages with skip connections (paper Fig. 2d).
Point operations are selectable:

* ``point_ops="global"`` — the PointAcc-style O(n^2) baseline (core/ref.py);
* ``point_ops="bppo"``   — Fractal partition + block-parallel ops (the
                           paper's contribution, core/bppo.py).

With ``point_ops="bppo"`` the execute phase of every point op additionally
dispatches through the kernel backend selected by ``PNNConfig.impl``:
``"xla"`` (jnp oracle) or ``"pallas"`` (TPU kernels, interpret off-TPU);
``None`` resolves from ``$REPRO_POINT_IMPL``.  Both backends differentiate
(kernels/vjp.py), so either is valid under ``jax.grad`` — training no
longer needs to wrap the model with ``impl="xla"``.  See docs/DESIGN.md §4
and ``train/pnn.py`` for the fine-tune loop.

Variants (simplified but structurally faithful; see docs/DESIGN.md §8):
* ``pointnet2``   — SA = group -> shared MLP -> max-pool.
* ``pointnext``   — SA + inverted-residual MLP blocks after aggregation.
* ``pointvector`` — SA with learned per-neighbor vector gating before pool.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro import core
from repro.core import ref

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SAStage:
    rate: float          # sampling rate (paper: one fixed rate per stage)
    radius: float
    nsample: int
    widths: tuple        # MLP widths applied to grouped features


@dataclasses.dataclass(frozen=True)
class PNNConfig:
    name: str = "pointnet2"
    variant: str = "pointnet2"       # pointnet2 | pointnext | pointvector
    task: str = "cls"                # cls | seg
    num_classes: int = 6
    n_points: int = 1024
    in_channels: int = 3
    stages: tuple = (
        SAStage(0.25, 0.2, 16, (32, 32, 64)),
        SAStage(0.25, 0.4, 16, (64, 64, 128)),
    )
    fp_widths: tuple = ((128, 64), (64, 64))   # seg only, reversed order
    head_widths: tuple = (128,)
    point_ops: str = "global"        # global | bppo
    impl: str | None = None          # bppo execute backend: xla | pallas |
                                     # None ($REPRO_POINT_IMPL, then xla)
    th: int = 64                     # Fractal threshold (paper: 64 cls /
                                     # 256 seg at full scale)
    strategy: str = "fractal"        # partition strategy, every stage
                                     # (core/fractal.py STRATEGIES)
    num_blocks: int = 1              # extra residual blocks (pointnext)
    leaf_chunk: int | None = None    # leaves per lax.map step (large scale)

    def stage_sizes(self):
        sizes = [self.n_points]
        for s in self.stages:
            sizes.append(max(1, int(round(sizes[-1] * s.rate))))
        return sizes


# ---------------------------------------------------------------------------
# Tiny functional NN helpers (params are nested dicts of arrays).
# ---------------------------------------------------------------------------

def _dense_init(key, din, dout):
    k1, _ = jax.random.split(key)
    w = jax.random.normal(k1, (din, dout)) * (2.0 / (din + dout)) ** 0.5
    return {"w": w.astype(jnp.float32), "b": jnp.zeros((dout,), jnp.float32)}


def _dense(p, x):
    return x @ p["w"] + p["b"]


def _ln_init(d):
    return {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def _ln(p, x, eps=1e-5):
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.var(x, -1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * p["g"] + p["b"]


def _mlp_init(key, din, widths):
    params = []
    for w in widths:
        key, sub = jax.random.split(key)
        params.append({"dense": _dense_init(sub, din, w), "ln": _ln_init(w)})
        din = w
    return params


def _mlp(params, x):
    for p in params:
        x = jax.nn.relu(_ln(p["ln"], _dense(p["dense"], x)))
    return x


# ---------------------------------------------------------------------------
# Point-op plumbing: one stage of sampling + grouping in either mode.
# ---------------------------------------------------------------------------

def _stage_points(cfg: PNNConfig, stage: SAStage, coords, feats, valid,
                  n_out, part=None):
    """Returns (new_coords (n_out,3), grouped (n_out, nsample, C+3),
    gmask, new_valid, ctx) running one sampling+grouping+gathering round.

    ``ctx`` carries what propagation needs (partition/samples for bppo,
    nothing for global).  ``part`` optionally supplies a precomputed
    FractalPartition of (coords, valid) — the serving plan cache
    (docs/DESIGN.md §9) partitions once per shape bucket and passes the
    plan in, so only the execute phase runs per request batch."""
    n = coords.shape[0]
    if cfg.point_ops == "global":
        sidx, svalid = ref.fps(coords, valid, n_out)
        centers = coords[sidx]
        nidx, cnt = ref.ball_query(coords, valid, centers, svalid,
                                   stage.radius, stage.nsample)
        gmask = (jnp.arange(stage.nsample)[None, :] <
                 jnp.minimum(cnt, stage.nsample)[:, None])
        gmask = gmask & svalid[:, None]
        gmask = gmask.at[:, 0].set(svalid)  # nearest pad always present
        rel = coords[nidx] - centers[:, None, :]
        gfeats = jnp.concatenate([rel, feats[nidx]], axis=-1)
        ctx = {"mode": "global", "coords": coords, "centers": centers,
               "svalid": svalid}
        return centers, gfeats, gmask, svalid, ctx

    if part is None:
        # Silent: this partition sits inside every jitted forward
        # (training steps and the deeper SA stages of serving) — no host
        # callback there.  Overflow is surfaced at the plan boundaries:
        # partition's own default ("warn"), the serve plan executable
        # (ServeConfig.on_overflow), the scene tiler, and check_overflow.
        part = core.partition(coords, valid, th=cfg.th,
                              strategy=cfg.strategy, on_overflow="silent")
    samp = core.blockwise_fps(part, rate=stage.rate, k_out=n_out, bs=cfg.th,
                              impl=cfg.impl)
    nb = core.blockwise_ball_query(part, samp, radius=stage.radius,
                                   num=stage.nsample, w=2 * cfg.th,
                                   chunk=cfg.leaf_chunk, impl=cfg.impl)
    feats_sorted = feats[part.perm]
    centers = samp.coords
    rel = core.gather(part.coords, nb.idx) - centers[:, None, :]
    gmask = nb.mask
    gmask = gmask.at[:, 0].set(samp.valid)
    gfeats = jnp.concatenate([rel, core.gather(feats_sorted, nb.idx)],
                             axis=-1)
    ctx = {"mode": "bppo", "part": part, "samp": samp}
    return centers, gfeats, gmask, samp.valid, ctx


def _propagate(cfg: PNNConfig, ctx, coarse_feats, fine_feats, fine_valid):
    """FP stage: interpolate coarse feats onto the fine cloud (3-NN IDW)."""
    if ctx["mode"] == "global":
        out, _, _ = ref.interpolate_3nn(
            ctx["coords"], ctx["centers"], ctx["svalid"], coarse_feats)
        return jnp.concatenate([out, fine_feats], axis=-1)
    part, samp = ctx["part"], ctx["samp"]
    wc = max(16, int(2 * cfg.th * cfg.stages[0].rate))
    out_sorted, _, _ = core.blockwise_interpolate(
        part, samp, coarse_feats, wc=wc, bs=cfg.th, chunk=cfg.leaf_chunk,
        impl=cfg.impl)
    fine_sorted = fine_feats[part.perm]
    merged = jnp.concatenate([out_sorted, fine_sorted], axis=-1)
    # back to the fine cloud's original order
    n = part.n
    inv = jnp.zeros((n,), jnp.int32).at[part.perm].set(
        jnp.arange(n, dtype=jnp.int32))
    return merged[inv]


# ---------------------------------------------------------------------------
# Model init / apply.
# ---------------------------------------------------------------------------

def init(key, cfg: PNNConfig):
    params = {"stages": [], "fp": [], "head": []}
    sizes = cfg.stage_sizes()
    c_in = cfg.in_channels
    for i, s in enumerate(cfg.stages):
        key, k1, k2, k3 = jax.random.split(key, 4)
        stage_p = {"mlp": _mlp_init(k1, c_in + 3, s.widths)}
        if cfg.variant == "pointvector":
            stage_p["vec"] = _dense_init(k2, c_in + 3, s.widths[-1])
        if cfg.variant == "pointnext":
            blocks = []
            for _ in range(cfg.num_blocks):
                key, kb = jax.random.split(key)
                blocks.append(_mlp_init(kb, s.widths[-1],
                                        (2 * s.widths[-1], s.widths[-1])))
            stage_p["res"] = blocks
        params["stages"].append(stage_p)
        c_in = s.widths[-1]
    if cfg.task == "seg":
        skip_dims = [cfg.in_channels] + \
            [s.widths[-1] for s in cfg.stages[:-1]]
        up_dim = cfg.stages[-1].widths[-1]
        for i, widths in enumerate(cfg.fp_widths):
            key, kf = jax.random.split(key)
            din = up_dim + skip_dims[-(i + 1)]
            params["fp"].append(_mlp_init(kf, din, widths))
            up_dim = widths[-1]
        head_in = up_dim
    else:
        head_in = cfg.stages[-1].widths[-1]
    key, kh, ko = jax.random.split(key, 3)
    params["head"] = _mlp_init(kh, head_in, cfg.head_widths)
    params["out"] = _dense_init(ko, cfg.head_widths[-1], cfg.num_classes)
    return params


def _aggregate(cfg, stage_p, gfeats, gmask, variant):
    h = _mlp(stage_p["mlp"], gfeats)                     # (m, ns, C')
    if variant == "pointvector":
        gate = jax.nn.sigmoid(_dense(stage_p["vec"], gfeats))
        h = h * gate
    h = jnp.where(gmask[..., None], h, -3.0e38)
    pooled = jnp.max(h, axis=-2)
    pooled = jnp.where(gmask.any(-1, keepdims=True), pooled, 0.0)
    if variant == "pointnext":
        for blk in stage_p["res"]:
            pooled = pooled + _mlp(blk, pooled)
    return pooled


def apply(params, cfg: PNNConfig, coords: Array, feats: Array | None = None,
          valid: Array | None = None, part0=None):
    """Single-cloud forward (vmap for batches).

    cls: returns (num_classes,) logits.
    seg: returns (n, num_classes) per-point logits.

    ``part0`` optionally injects a precomputed stage-0 FractalPartition of
    (coords, valid) (bppo only; ignored for global ops) — the serving plan
    cache builds it once per shape bucket (docs/DESIGN.md §9).
    """
    n = coords.shape[0]
    if valid is None:
        valid = jnp.ones((n,), bool)
    if feats is None:
        feats = coords
    sizes = cfg.stage_sizes()
    skips = [(coords, feats, valid)]
    ctxs = []
    for i, s in enumerate(cfg.stages):
        centers, gfeats, gmask, svalid, ctx = _stage_points(
            cfg, s, skips[-1][0], skips[-1][1], skips[-1][2], sizes[i + 1],
            part=part0 if i == 0 else None)
        pooled = _aggregate(cfg, params["stages"][i], gfeats, gmask,
                            cfg.variant)
        ctxs.append(ctx)
        skips.append((centers, pooled, svalid))

    if cfg.task == "cls":
        _, f, v = skips[-1]
        f = jnp.where(v[:, None], f, -3.0e38)
        g = jnp.max(f, axis=0)
        h = _mlp(params["head"], g)
        return _dense(params["out"], h)

    up = skips[-1][1]
    for i, widths in enumerate(cfg.fp_widths):
        lvl = len(cfg.stages) - 1 - i
        fine_coords, fine_feats, fine_valid = skips[lvl]
        merged = _propagate(cfg, ctxs[lvl], up, fine_feats, fine_valid)
        up = _mlp(params["fp"][i], merged)
    h = _mlp(params["head"], up)
    return _dense(params["out"], h)


# Paper Table I model presets -------------------------------------------------

def pointnet2_cls(n=1024, point_ops="global", th=64, impl=None):
    return PNNConfig(name="pointnet2_cls", variant="pointnet2", task="cls",
                     n_points=n, point_ops=point_ops, th=th, impl=impl)


def pointnext_cls(n=1024, point_ops="global", th=64, impl=None):
    return PNNConfig(name="pointnext_cls", variant="pointnext", task="cls",
                     n_points=n, point_ops=point_ops, th=th, impl=impl)


def pointnet2_seg(n=2048, point_ops="global", th=256, impl=None):
    return PNNConfig(name="pointnet2_seg", variant="pointnet2", task="seg",
                     n_points=n, point_ops=point_ops, th=th, impl=impl)


def pointnext_seg(n=2048, point_ops="global", th=256, impl=None):
    return PNNConfig(name="pointnext_seg", variant="pointnext", task="seg",
                     n_points=n, point_ops=point_ops, th=th, impl=impl)


def pointvector_seg(n=2048, point_ops="global", th=256, impl=None):
    return PNNConfig(name="pointvector_seg", variant="pointvector",
                     task="seg", n_points=n, point_ops=point_ops, th=th,
                     impl=impl)


def scene_seg(n=4096, th=256, impl=None, widths=(32, 32, 64),
              fp=(64, 64), rate=0.25, radius=0.25, nsample=16):
    """Single-SA-stage segmentation config for scene tiling (DESIGN.md §10).

    With exactly one abstraction stage, every point op runs inside the
    stage-0 partition — the one ``apply(part0=...)`` accepts from outside
    — so tile-wise execution over exact fractal subtrees (``repro.scene``
    with ``halo=0`` and per-tile ``dim0``) reproduces the whole-scene
    forward to float precision (tests/test_scene.py).  Multi-stage
    configs re-partition their sampled cloud per tile and are therefore
    approximate at tile borders; the halo ring is the quality knob there.
    """
    return PNNConfig(name="scene_seg", variant="pointnet2", task="seg",
                     n_points=n, point_ops="bppo", th=th, impl=impl,
                     stages=(SAStage(rate, radius, nsample, widths),),
                     fp_widths=(fp,))

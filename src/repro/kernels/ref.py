"""Pure-jnp oracles mirroring each Pallas kernel's exact contract.

Tests sweep shapes/dtypes and assert_allclose kernel-vs-oracle; tie-breaking
(argmax/argmin pick the first extremum) matches by construction because both
sides evaluate the same formulas in the same order.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import INF, NEG


def _fps_one(coords, vmask, k):
    """coords (3, BS), vmask (BS,) -> (k,) i32.

    Exhaustion contract: once every valid point has been selected (k larger
    than the block's valid count), the remaining slots *repeat the last
    valid selection* instead of emitting whatever argmax of an all-pinned
    vector lands on.  An empty block degenerates to repeating index 0."""
    c = coords.astype(jnp.float32)
    v = vmask > 0
    bs = c.shape[-1]

    def d2_to(i):
        diff = c - c[:, i][:, None]
        return jnp.sum(diff * diff, axis=0)

    start = jnp.argmax(v.astype(jnp.float32)).astype(jnp.int32)
    iot = jnp.arange(bs, dtype=jnp.int32)
    mind = jnp.where(v, d2_to(start), NEG)
    mind = jnp.where(iot == start, NEG, mind)

    def step(carry, _):
        m, prev = carry
        # Unselected valid lanes hold d2 >= 0 > NEG; all-pinned means done.
        nxt = jnp.where(jnp.max(m) > NEG,
                        jnp.argmax(m).astype(jnp.int32), prev)
        m = jnp.minimum(m, jnp.where(v, d2_to(nxt), NEG))
        m = jnp.where(iot == nxt, NEG, m)
        return (m, nxt), nxt

    _, rest = jax.lax.scan(step, (mind, start), None, length=k - 1)
    return jnp.concatenate([start[None], rest])


def fps_blocks(coords, vmask, *, k):
    return jax.vmap(lambda c, m: _fps_one(c, m[0], k))(
        coords, vmask)


def _topk_min(d, num):
    iot = jnp.arange(d.shape[-1], dtype=jnp.int32)[None, :]
    idxs, vals = [], []
    for _ in range(num):
        v = jnp.min(d, axis=-1)
        i = jnp.argmin(d, axis=-1).astype(jnp.int32)
        idxs.append(i)
        vals.append(v)
        d = jnp.where(iot == i[:, None], INF, d)
    return jnp.stack(idxs, -1), jnp.stack(vals, -1)


def _sqdist(a, b):
    a2 = jnp.sum(a * a, axis=0)[:, None]
    b2 = jnp.sum(b * b, axis=0)[None, :]
    return a2 + b2 - 2.0 * (a.T @ b)


def ball_query_blocks(centers, cmask, window, wmask, *, radius, num):
    r2 = jnp.float32(radius) ** 2

    def one(c, cm, w, wm):
        d = _sqdist(c.astype(jnp.float32), w.astype(jnp.float32))
        d = jnp.where(wm[0] > 0, d, INF)
        cnt = jnp.where(cm[0] > 0,
                        jnp.sum(((d <= r2) & (wm[0][None, :] > 0)),
                                axis=-1), 0).astype(jnp.int32)
        idx, val = _topk_min(d, num)
        return idx, val, cnt

    return jax.vmap(one)(centers, cmask, window, wmask)


def knn_blocks(queries, window, wmask, *, k):
    def one(q, w, wm):
        d = _sqdist(q.astype(jnp.float32), w.astype(jnp.float32))
        d = jnp.where(wm[0] > 0, d, INF)
        return _topk_min(d, k)

    return jax.vmap(one)(queries, window, wmask)


def gather_blocks(window_feats, idx):
    """Out-of-range idx (negative or >= W) fetches zeros — the one-hot
    kernel's contract, which the backward relies on to drop their rows."""
    w = window_feats.shape[-2]

    def one(f, i):
        ok = (i >= 0) & (i < w)
        return jnp.where(ok[:, None], f[jnp.clip(i, 0, w - 1)], 0)

    return jax.vmap(one)(window_feats, idx)


def scatter_add_blocks(g, idx, *, w):
    """gather_blocks' backward oracle: g (NB, M, C), idx (NB, M) ->
    (NB, W, C); out-of-range idx rows are dropped (their forward rows
    fetched zeros)."""
    c = g.shape[-1]

    def one(gg, i):
        ok = (i >= 0) & (i < w)
        safe = jnp.clip(i, 0, w - 1)
        return jnp.zeros((w, c), g.dtype).at[safe].add(
            jnp.where(ok[:, None], gg, 0))

    return jax.vmap(one)(g, idx)


def fractal_level_blocks(coords, vmask, mid, *, da, db):
    def one(c, vm, m):
        v = vm[0] > 0
        xa, xb = c[da], c[db]
        side = (xa > m[0]) & v
        left = v & ~side
        stats = jnp.stack([
            jnp.min(jnp.where(left, xb, INF)),
            jnp.max(jnp.where(left, xb, NEG)),
            jnp.min(jnp.where(side, xb, INF)),
            jnp.max(jnp.where(side, xb, NEG)),
        ])
        return side.astype(jnp.int32), jnp.sum(left.astype(jnp.int32)), stats

    return jax.vmap(one)(coords, vmask, mid)

"""Fractal-engine Pallas kernel — the pipelined partition step (paper §V-B).

Paper Fig. 9(b,c): the partition unit and the midpoint-computation unit run
*pipelined* — iteration l partitions on dimension d using the mid computed
one iteration earlier, while simultaneously computing the children's
min/max on dimension d+1.  This kernel fuses exactly those two stages into
one linear VMEM pass per node:

  inputs : node coords (3, BS), validity, this node's split value `mid`
  outputs: side bits, left count (the ASIC counter), and the four child
           extrema on the *next* dimension (lmin, lmax, rmin, rmax) from
           which the host derives both children's mids with one add+shift
           (min-max averaging, paper §V-B) — no second traversal.

The layout scatter (prefix-sum destinations) stays in XLA: it is a
permutation, not a traversal, and XLA already streams it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import INF, NEG


def _level_kernel(coords_ref, vmask_ref, mid_ref, side_ref, lcnt_ref,
                  stats_ref, *, da: int, db: int):
    c = coords_ref[0]            # (3, BS)
    v = vmask_ref[0] > 0         # (1, BS)
    mid = mid_ref[0, 0]
    xa = c[da][None, :]
    xb = c[db][None, :]
    side = (xa > mid) & v
    side_ref[...] = side.astype(jnp.int32)
    left = v & ~side
    lcnt_ref[0, 0] = jnp.sum(left.astype(jnp.int32))
    stats_ref[0, 0] = jnp.min(jnp.where(left, xb, INF))
    stats_ref[0, 1] = jnp.max(jnp.where(left, xb, NEG))
    stats_ref[0, 2] = jnp.min(jnp.where(side, xb, INF))
    stats_ref[0, 3] = jnp.max(jnp.where(side, xb, NEG))


@functools.partial(jax.jit, static_argnames=("da", "db", "interpret"))
def fractal_level_blocks(coords: jax.Array, vmask: jax.Array,
                         mid: jax.Array, *, da: int, db: int,
                         interpret: bool = True):
    """coords (NB,3,BS), vmask (NB,1,BS), mid (NB,1) ->
    (side (NB,BS) i32, left_count (NB,) i32, child_stats (NB,4) f32
     = [lmin_b, lmax_b, rmin_b, rmax_b])."""
    nb, _, bs = coords.shape
    kernel = functools.partial(_level_kernel, da=da, db=db)
    side, lcnt, stats = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, 3, bs), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 1, bs), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 1), lambda b: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bs), lambda b: (b, 0)),
            pl.BlockSpec((1, 1), lambda b: (b, 0)),
            pl.BlockSpec((1, 4), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, bs), jnp.int32),
            jax.ShapeDtypeStruct((nb, 1), jnp.int32),
            jax.ShapeDtypeStruct((nb, 4), jnp.float32),
        ],
        interpret=interpret,
    )(coords.astype(jnp.float32), vmask.astype(jnp.float32),
      mid.astype(jnp.float32))
    return side, lcnt[:, 0], stats

"""Shared helpers for the FractalCloud Pallas TPU kernels.

TPU notes (kernels are *targeted* at TPU v5e, validated in interpret mode):

* vectors are kept 2-D ``(1, L)`` / ``(R, L)`` with the large axis last so it
  lands on the 128-wide lane dimension;
* dynamic gathers inside VMEM are expressed as one-hot reductions/matmuls
  (iota == idx), which lower to VPU selects / MXU dots instead of scatters;
* loop counts (k, num) are static and small, so selection loops unroll.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

# Plain Python floats: Pallas kernel bodies may not capture device constants.
NEG = -3.0e38
INF = 3.0e38


def row_iota(n: int, dtype=jnp.int32):
    """(1, n) iota along lanes (TPU requires >=2D iota)."""
    return lax.broadcasted_iota(dtype, (1, n), 1)


def onehot_rows(idx, n: int, dtype=jnp.float32):
    """idx (r,) -> (r, n) one-hot along lanes."""
    iot = lax.broadcasted_iota(jnp.int32, (idx.shape[0], n), 1)
    return (iot == idx[:, None]).astype(dtype)


def select_coord(coords, idx):
    """coords (3, n), scalar idx -> (3,) gathered via one-hot reduction."""
    oh = (lax.broadcasted_iota(jnp.int32, coords.shape, 1) == idx)
    return jnp.sum(jnp.where(oh, coords, 0.0), axis=1)


def sqdist_rows(a, b):
    """a (3, r), b (3, n) -> (r, n) squared distances (expanded form so the
    cross term is a (r,3)x(3,n) contraction)."""
    a2 = jnp.sum(a * a, axis=0)[:, None]
    b2 = jnp.sum(b * b, axis=0)[None, :]
    cross = jnp.dot(a.T, b, preferred_element_type=jnp.float32)
    return a2 + b2 - 2.0 * cross


def argmin_extract(d, num: int):
    """d (r, n): extract indices/values of the num smallest per row by
    repeated masked min (the TPU analogue of the paper's merge-sort top-k
    unit).  Returns (idx (r, num) i32, val (r, num))."""
    r, n = d.shape
    iot = lax.broadcasted_iota(jnp.int32, (r, n), 1)
    idxs, vals = [], []
    for _ in range(num):
        v = jnp.min(d, axis=1)
        i = jnp.argmin(d, axis=1).astype(jnp.int32)
        idxs.append(i)
        vals.append(v)
        d = jnp.where(iot == i[:, None], INF, d)
    return jnp.stack(idxs, axis=1), jnp.stack(vals, axis=1)

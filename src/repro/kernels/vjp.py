"""Custom VJPs for the execute-phase dispatch ops (docs/DESIGN.md §4).

The plan/execute split makes training cheap to support: the *plan* phase
(core/bppo.py) is pure jnp index math and always differentiable, so only
the execute ops need gradient rules — and of those, only the ops that move
*features* carry useful cotangents.  The contract, uniform across impls:

* ``gather_blocks`` differentiates in ``window_feats``; its backward is the
  transposed one-hot scatter-add into the window tile (pallas: the same MXU
  one-hot matmul as the forward, transposed; xla: a masked ``.at[].add``).
  Out-of-range indices (negative, or >= W) fetched zeros in the forward, so
  they receive nothing in the backward.
* FPS / ball query / kNN / fractal-level are *index producers*: their
  outputs (indices, counts, the d2 distances the plan layer turns into IDW
  weights, split-side stats) are functions of coordinates only, never of
  parameters, so they are declared non-differentiable — every output
  carries a zero cotangent back to every input.  This is stop-gradient
  semantics, applied at the dispatch layer so both backends agree under
  ``jax.grad`` (tests/test_grads.py asserts the zero cotangents).

These combinators are wired onto the public wrappers by ``kernels/ops.py``
(one cached ``custom_vjp`` instance per static-arg signature); they take
already-specialized callables so this module needs no knowledge of the
dispatch layer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def zero_cotangent(x):
    """A zero cotangent matching ``x``: float zeros for inexact dtypes,
    ``float0`` (the tangent type of ints/bools) otherwise."""
    if jnp.issubdtype(jnp.result_type(x), jnp.inexact):
        return jnp.zeros_like(x)
    return np.zeros(jnp.shape(x), jax.dtypes.float0)


def index_producer(fn):
    """Wrap a specialized dispatch callable as a non-differentiable
    index/plan producer: primal output of ``fn``, zero cotangents to every
    input.  ``fn`` must be positional-only (statics already bound)."""

    @jax.custom_vjp
    def op(*args):
        return fn(*args)

    def fwd(*args):
        # Residuals are the args themselves, used only for their shapes —
        # zero_cotangent reads avals, not values, so jit DCEs the data
        # dependence.
        return fn(*args), args

    def bwd(args, _g):
        return tuple(zero_cotangent(a) for a in args)

    op.defvjp(fwd, bwd)
    return op


def gathering(fwd_fn, bwd_fn):
    """Wrap a specialized gather dispatch as differentiable-in-features.

    ``fwd_fn(window_feats, idx) -> (NB, M, C)``;
    ``bwd_fn(g, idx) -> (NB, W, C)`` scatter-adds the cotangent rows back
    into the window tile (W is bound statically by the caller).  ``idx``
    gets a float0 cotangent."""

    @jax.custom_vjp
    def op(window_feats, idx):
        return fwd_fn(window_feats, idx)

    def fwd(window_feats, idx):
        return fwd_fn(window_feats, idx), idx

    def bwd(idx, g):
        return bwd_fn(g, idx), zero_cotangent(idx)

    op.defvjp(fwd, bwd)
    return op

"""FractalCloud Pallas TPU kernels.

Per kernel: ``<name>.py`` holds the ``pl.pallas_call`` + BlockSpec tiling,
``ref.py`` the pure-jnp oracle with the identical contract, ``ops.py`` the
jit'd public wrappers — the dispatch layer owning lane-major padding,
leaf-chunking, and xla/pallas impl selection (docs/DESIGN.md §4).
"""
from repro.kernels import ops
from repro.kernels.ops import (ball_query_blocks, fps_blocks,
                               fractal_level_blocks, gather_blocks,
                               knn_blocks)

__all__ = ["ops", "fps_blocks", "ball_query_blocks", "knn_blocks",
           "gather_blocks", "fractal_level_blocks"]

"""jit'd public wrappers for the FractalCloud kernels.

Each op accepts ``impl``:

* ``"pallas"``    — the TPU kernel (interpret=True off-TPU, compiled on TPU);
* ``"xla"``       — the pure-jnp oracle (kernels/ref.py), which is also what
                    core/bppo.py uses by default on CPU.

Wrappers own the layout contract: user-facing tensors are (NB, BS, 3) /
(NB, BS); kernels consume lane-major (NB, 3, BS') with BS' padded to the
128-lane boundary (padded lanes masked invalid).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ball_query as _bq
from repro.kernels import fps as _fps
from repro.kernels import fractal_engine as _fe
from repro.kernels import gather as _ga
from repro.kernels import knn as _knn
from repro.kernels import ref as _ref

LANE = 128


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_lanes(x, axis, mult=LANE, value=0):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _to_lane_major(coords, mask):
    """(NB, BS, 3), (NB, BS) -> (NB, 3, BS'), (NB, 1, BS')."""
    c = _pad_lanes(jnp.swapaxes(coords, -1, -2), -1)
    m = _pad_lanes(mask.astype(jnp.float32)[:, None, :], -1)
    return c, m


@functools.partial(jax.jit, static_argnames=("k", "impl"))
def fps_blocks(coords, mask, *, k: int, impl: str = "pallas"):
    """coords (NB, BS, 3), mask (NB, BS) -> sampled in-block idx (NB, k)."""
    c, m = _to_lane_major(coords, mask)
    if impl == "pallas":
        return _fps.fps_blocks(c, m, k=k, interpret=not _on_tpu())
    return _ref.fps_blocks(c, m, k=k)


@functools.partial(jax.jit, static_argnames=("radius", "num", "impl"))
def ball_query_blocks(centers, cmask, window, wmask, *, radius: float,
                      num: int, impl: str = "pallas"):
    """centers (NB,KC,3), cmask (NB,KC), window (NB,W,3), wmask (NB,W)
    -> (idx (NB,KC,num) local-to-window, d2, cnt (NB,KC))."""
    c, cm = _to_lane_major(centers, cmask)
    w, wm = _to_lane_major(window, wmask)
    if impl == "pallas":
        return _bq.ball_query_blocks(c, cm, w, wm, radius=radius, num=num,
                                     interpret=not _on_tpu())
    return _ref.ball_query_blocks(c, cm, w, wm, radius=radius, num=num)


@functools.partial(jax.jit, static_argnames=("k", "impl"))
def knn_blocks(queries, window, wmask, *, k: int, impl: str = "pallas"):
    """queries (NB,Q,3), window (NB,W,3), wmask (NB,W)
    -> (idx (NB,Q,k) local-to-window, d2)."""
    q, _ = _to_lane_major(queries, jnp.ones(queries.shape[:2], bool))
    w, wm = _to_lane_major(window, wmask)
    if impl == "pallas":
        return _knn.knn_blocks(q, w, wm, k=k, interpret=not _on_tpu())
    return _ref.knn_blocks(q, w, wm, k=k)


@functools.partial(jax.jit, static_argnames=("impl",))
def gather_blocks(window_feats, idx, *, impl: str = "pallas"):
    """window_feats (NB, W, C), idx (NB, M) -> (NB, M, C)."""
    if impl == "pallas":
        f = _pad_lanes(window_feats, -1)          # C on lanes
        f = _pad_lanes(f, -2, mult=8)             # W on sublanes
        out = _ga.gather_blocks(f, idx, interpret=not _on_tpu())
        return out[..., :window_feats.shape[-1]]
    return _ref.gather_blocks(window_feats, idx)


@functools.partial(jax.jit, static_argnames=("da", "db", "impl"))
def fractal_level_blocks(coords, mask, mid, *, da: int, db: int,
                         impl: str = "pallas"):
    """coords (NB,BS,3), mask (NB,BS), mid (NB,) ->
    (side (NB,BS) i32, left_count (NB,), child_stats (NB,4))."""
    bs = coords.shape[1]
    c, m = _to_lane_major(coords, mask)
    if impl == "pallas":
        side, lcnt, stats = _fe.fractal_level_blocks(
            c, m, mid[:, None], da=da, db=db, interpret=not _on_tpu())
    else:
        side, lcnt, stats = _ref.fractal_level_blocks(
            c, m, mid[:, None], da=da, db=db)
    return side[:, :bs], lcnt, stats

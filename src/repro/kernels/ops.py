"""jit'd public wrappers for the FractalCloud kernels — the *dispatch layer*.

Each op accepts ``impl``:

* ``"pallas"``    — the TPU kernel (interpret=True off-TPU, compiled on TPU);
* ``"xla"``       — the pure-jnp oracle (kernels/ref.py);
* ``None``        — resolved from ``$REPRO_POINT_IMPL`` (default ``"pallas"``
                    here at the kernel layer; ``core/bppo.py`` defaults its
                    callers to ``"xla"``).

This layer owns the whole execution contract so callers never re-implement
it ad hoc (docs/DESIGN.md §4):

* *layout* — user-facing tensors are (NB, BS, 3) / (NB, BS); kernels consume
  lane-major (NB, 3, BS') with BS' padded to the 128-lane boundary (padded
  lanes masked invalid) and results sliced back to caller shapes;
* *leaf-chunking* — every op takes ``chunk``: the block axis is processed
  ``chunk`` blocks per ``lax.map`` step, bounding the live distance /
  gather-tile footprint at large scale (``leaf_chunks`` is the shared
  pad+reshape helper).

``impl=None`` is resolved eagerly in the public wrappers, before the jitted
inner functions (whose caches key on the concrete impl) — flipping
``$REPRO_POINT_IMPL`` mid-process affects the next eager call, never a
stale jit cache.  Inside an outer jit, resolution still happens at that
trace's time.

Both impls are trainable end to end: every public wrapper carries a custom
VJP (``kernels/vjp.py``) — ``gather_blocks`` differentiates in its features
(backward = transposed one-hot scatter-add, dispatched like the forward),
and FPS / ball query / kNN / fractal-level are non-differentiable index
producers whose outputs carry zero cotangents.  One ``custom_vjp`` instance
is cached per static-arg signature, so jit caches stay keyed the same way.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ball_query as _bq
from repro.kernels import fps as _fps
from repro.kernels import fractal_engine as _fe
from repro.kernels import gather as _ga
from repro.kernels import knn as _knn
from repro.kernels import ref as _ref
from repro.kernels import vjp as _vjp

LANE = 128
IMPLS = ("xla", "pallas")


def resolve_impl(impl: str | None = None, default: str = "pallas") -> str:
    """Resolve an impl choice: explicit arg > $REPRO_POINT_IMPL > default."""
    if impl is None:
        impl = os.environ.get("REPRO_POINT_IMPL") or default
    if impl not in IMPLS:
        raise ValueError(f"impl must be one of {IMPLS}, got {impl!r}")
    return impl


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_lanes(x, axis, mult=LANE, value=0):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _to_lane_major(coords, mask):
    """(NB, BS, 3), (NB, BS) -> (NB, 3, BS'), (NB, 1, BS')."""
    c = _pad_lanes(jnp.swapaxes(coords, -1, -2), -1)
    m = _pad_lanes(mask.astype(jnp.float32)[:, None, :], -1)
    return c, m


def pad_points(coords, n: int, valid=None):
    """Admission-time bucket padding: grow a ``(..., p, 3)`` cloud to exactly
    ``n`` points, marking the tail invalid.

    The serving layer's analogue of this module's lane padding (see
    docs/DESIGN.md §9): padded slots carry a ``False`` mask and are never
    observed, so every cloud admitted to a shape bucket hits the one cached
    executable compiled for that bucket.  Returns ``(coords, valid)`` with
    shapes ``(..., n, 3)`` / ``(..., n)``.
    """
    p = coords.shape[-2]
    if n < p:
        raise ValueError(f"cannot pad {p} points down to {n}")
    if valid is None:
        valid = jnp.ones(coords.shape[:-1], bool)
    pad = n - p
    if pad:
        wc = [(0, 0)] * coords.ndim
        wc[-2] = (0, pad)
        coords = jnp.pad(coords, wc)
        wv = [(0, 0)] * valid.ndim
        wv[-1] = (0, pad)
        valid = jnp.pad(valid, wv)
    return coords, valid


def leaf_chunks(arrays, chunk):
    """Pad leading (block) dims to a chunk multiple and reshape to
    (n_chunks, chunk, ...) for lax.map/scan over block chunks.  Returns
    (chunked arrays, original leading size).

    Public: callers that stream a custom carry over chunks (e.g. bppo's
    interpolation scatter-scan) build their chunk layout here so the
    pad/reshape contract lives in one place."""
    nb = arrays[0].shape[0]
    pad = (-nb) % chunk

    def prep(a):
        if pad:
            a = jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
        return a.reshape((nb + pad) // chunk, chunk, *a.shape[1:])

    return tuple(prep(a) for a in arrays), nb


def _chunked(fn, arrays, chunk):
    """Apply ``fn`` to ``chunk``-block slices of the leading axis via
    lax.map (padded blocks carry zero masks and are sliced off)."""
    nb = arrays[0].shape[0]
    if chunk is None or chunk >= nb:
        return fn(*arrays)
    chunks, _ = leaf_chunks(arrays, chunk)
    out = jax.lax.map(lambda xs: fn(*xs), chunks)
    return jax.tree.map(lambda a: a.reshape(-1, *a.shape[2:])[:nb], out)


@functools.lru_cache(maxsize=None)
def _fps_op(k: int, impl: str, chunk: int | None):
    return _vjp.index_producer(
        functools.partial(_fps_blocks, k=k, impl=impl, chunk=chunk))


def fps_blocks(coords, mask, *, k: int, impl: str | None = None,
               chunk: int | None = None):
    """coords (NB, BS, 3), mask (NB, BS) -> sampled in-block idx (NB, k).

    If ``k`` exceeds a block's valid count, the exhausted slots repeat the
    last valid selection (empty blocks repeat index 0) — both impls,
    asserted in tests/test_point_impls.py."""
    return _fps_op(k, resolve_impl(impl), chunk)(coords, mask)


@functools.partial(jax.jit, static_argnames=("k", "impl", "chunk"))
def _fps_blocks(coords, mask, *, k, impl, chunk):
    def run(coords, mask):
        c, m = _to_lane_major(coords, mask)
        if impl == "pallas":
            return _fps.fps_blocks(c, m, k=k, interpret=not _on_tpu())
        return _ref.fps_blocks(c, m, k=k)

    return _chunked(run, (coords, mask), chunk)


@functools.lru_cache(maxsize=None)
def _ball_query_op(radius: float, num: int, impl: str, chunk: int | None):
    return _vjp.index_producer(
        functools.partial(_ball_query_blocks, radius=radius, num=num,
                          impl=impl, chunk=chunk))


def ball_query_blocks(centers, cmask, window, wmask, *, radius: float,
                      num: int, impl: str | None = None,
                      chunk: int | None = None):
    """centers (NB,KC,3), cmask (NB,KC), window (NB,W,3), wmask (NB,W)
    -> (idx (NB,KC,num) local-to-window, d2 (NB,KC,num), cnt (NB,KC))."""
    return _ball_query_op(radius, num, resolve_impl(impl), chunk)(
        centers, cmask, window, wmask)


@functools.partial(jax.jit,
                   static_argnames=("radius", "num", "impl", "chunk"))
def _ball_query_blocks(centers, cmask, window, wmask, *, radius, num, impl,
                       chunk):
    kc = centers.shape[1]

    def run(centers, cmask, window, wmask):
        c, cm = _to_lane_major(centers, cmask)
        w, wm = _to_lane_major(window, wmask)
        if impl == "pallas":
            idx, d2, cnt = _bq.ball_query_blocks(
                c, cm, w, wm, radius=radius, num=num,
                interpret=not _on_tpu())
        else:
            idx, d2, cnt = _ref.ball_query_blocks(c, cm, w, wm,
                                                  radius=radius, num=num)
        return idx[:, :kc], d2[:, :kc], cnt[:, :kc]

    return _chunked(run, (centers, cmask, window, wmask), chunk)


@functools.lru_cache(maxsize=None)
def _knn_op(k: int, impl: str, chunk: int | None):
    return _vjp.index_producer(
        functools.partial(_knn_blocks, k=k, impl=impl, chunk=chunk))


def knn_blocks(queries, window, wmask, *, k: int, impl: str | None = None,
               chunk: int | None = None):
    """queries (NB,Q,3), window (NB,W,3), wmask (NB,W)
    -> (idx (NB,Q,k) local-to-window, d2 (NB,Q,k))."""
    return _knn_op(k, resolve_impl(impl), chunk)(queries, window, wmask)


@functools.partial(jax.jit, static_argnames=("k", "impl", "chunk"))
def _knn_blocks(queries, window, wmask, *, k, impl, chunk):
    nq = queries.shape[1]

    def run(queries, window, wmask):
        q, _ = _to_lane_major(queries, jnp.ones(queries.shape[:2], bool))
        w, wm = _to_lane_major(window, wmask)
        if impl == "pallas":
            idx, d2 = _knn.knn_blocks(q, w, wm, k=k,
                                      interpret=not _on_tpu())
        else:
            idx, d2 = _ref.knn_blocks(q, w, wm, k=k)
        return idx[:, :nq], d2[:, :nq]

    return _chunked(run, (queries, window, wmask), chunk)


@functools.lru_cache(maxsize=None)
def _gather_op(w: int, impl: str, chunk: int | None):
    return _vjp.gathering(
        functools.partial(_gather_blocks, impl=impl, chunk=chunk),
        functools.partial(_gather_grad_blocks, w=w, impl=impl, chunk=chunk))


def gather_blocks(window_feats, idx, *, impl: str | None = None,
                  chunk: int | None = None):
    """window_feats (NB, W, C), idx (NB, M) local-to-window -> (NB, M, C).

    Out-of-range idx (negative or >= W) fetches zeros, both impls — the
    masked-invalid contract the backward mirrors by dropping those rows.
    Differentiable in ``window_feats`` (kernels/vjp.py)."""
    return _gather_op(window_feats.shape[-2], resolve_impl(impl), chunk)(
        window_feats, idx)


@functools.partial(jax.jit, static_argnames=("impl", "chunk"))
def _gather_blocks(window_feats, idx, *, impl, chunk):
    c_out = window_feats.shape[-1]

    def run(window_feats, idx):
        if impl == "pallas":
            f = _pad_lanes(window_feats, -1)          # C on lanes
            f = _pad_lanes(f, -2, mult=8)             # W on sublanes
            out = _ga.gather_blocks(f, idx, interpret=not _on_tpu())
            return out[..., :c_out]
        return _ref.gather_blocks(window_feats, idx)

    return _chunked(run, (window_feats, idx), chunk)


@functools.partial(jax.jit, static_argnames=("w", "impl", "chunk"))
def _gather_grad_blocks(g, idx, *, w, impl, chunk):
    """gather_blocks' backward dispatch: cotangent rows g (NB, M, C)
    scatter-added at idx into (NB, W, C) window cotangents."""
    c_out = g.shape[-1]

    def run(g, idx):
        if impl == "pallas":
            gg = _pad_lanes(g, -1)                    # C on lanes
            gg = _pad_lanes(gg, -2)                   # M: contraction dim,
            ii = _pad_lanes(idx, -1, value=-1)        # padded rows dropped
            out = _ga.scatter_add_blocks(gg, ii, w=w + (-w) % 8,
                                         interpret=not _on_tpu())
            return out[:, :w, :c_out]
        return _ref.scatter_add_blocks(g, idx, w=w)

    return _chunked(run, (g, idx), chunk)


@functools.lru_cache(maxsize=None)
def _fractal_level_op(da: int, db: int, impl: str, chunk: int | None):
    return _vjp.index_producer(
        functools.partial(_fractal_level_blocks, da=da, db=db, impl=impl,
                          chunk=chunk))


def fractal_level_blocks(coords, mask, mid, *, da: int, db: int,
                         impl: str | None = None, chunk: int | None = None):
    """coords (NB,BS,3), mask (NB,BS), mid (NB,) ->
    (side (NB,BS) i32, left_count (NB,), child_stats (NB,4))."""
    return _fractal_level_op(da, db, resolve_impl(impl), chunk)(
        coords, mask, mid)


@functools.partial(jax.jit, static_argnames=("da", "db", "impl", "chunk"))
def _fractal_level_blocks(coords, mask, mid, *, da, db, impl, chunk):
    bs = coords.shape[1]

    def run(coords, mask, mid):
        c, m = _to_lane_major(coords, mask)
        if impl == "pallas":
            side, lcnt, stats = _fe.fractal_level_blocks(
                c, m, mid[:, None], da=da, db=db, interpret=not _on_tpu())
        else:
            side, lcnt, stats = _ref.fractal_level_blocks(
                c, m, mid[:, None], da=da, db=db)
        return side[:, :bs], lcnt, stats

    return _chunked(run, (coords, mask, mid), chunk)

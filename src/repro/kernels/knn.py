"""Block-wise k-NN Pallas kernel — RSPU interpolation mode (paper §V-C).

Same VMEM-resident window structure as ball query, without the radius
constraint: used for the 3-NN search of block-wise interpolation (BWI).
Queries here are *all* points of a fine leaf; candidates are the coarse
samples of the leaf's parent subtree.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import INF, argmin_extract, sqdist_rows


def _knn_kernel(q_ref, w_ref, wmask_ref, idx_ref, d2_ref, *, k: int):
    q = q_ref[0]                 # (3, Q)
    w = w_ref[0]                 # (3, W)
    wm = wmask_ref[0] > 0        # (1, W)
    d = sqdist_rows(q, w)        # (Q, W)
    d = jnp.where(wm, d, INF)
    idx, val = argmin_extract(d, k)
    idx_ref[0] = idx
    d2_ref[0] = val


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def knn_blocks(queries: jax.Array, window: jax.Array, wmask: jax.Array, *,
               k: int, interpret: bool = True):
    """queries (NB,3,Q), window (NB,3,W), wmask (NB,1,W)
    -> (idx (NB,Q,k) i32 local-to-window, d2 (NB,Q,k))."""
    nb, _, q = queries.shape
    w = window.shape[-1]
    kernel = functools.partial(_knn_kernel, k=k)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, 3, q), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 3, w), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 1, w), lambda b: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, k), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, q, k), lambda b: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, q, k), jnp.int32),
            jax.ShapeDtypeStruct((nb, q, k), jnp.float32),
        ],
        interpret=interpret,
    )(queries.astype(jnp.float32), window.astype(jnp.float32),
      wmask.astype(jnp.float32))

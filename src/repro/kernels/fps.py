"""Block-parallel FPS Pallas kernel — the RSPU sampling mode (paper §V-C).

One grid step = one Fractal leaf (the paper's inter-block parallelism): the
block's coordinates live in VMEM for the whole FPS loop, the running
min-distance vector is a VMEM scratch, and the ASIC's window-check skip is
realized as masking (visited lanes pinned to -inf; see docs/DESIGN.md §2).

Layout: coords are (NB, 3, BS) so the point axis is the 128-lane axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import NEG, select_coord


def _fps_kernel(coords_ref, vmask_ref, idx_ref, mind_ref, prev_ref, *,
                k: int):
    c = coords_ref[0]          # (3, BS)
    v = vmask_ref[0] > 0       # (1, BS)
    bs = c.shape[-1]

    def d2_to(i):
        p = select_coord(c, i)                        # (3,)
        diff = c - p[:, None]
        return jnp.sum(diff * diff, axis=0)[None, :]  # (1, BS)

    # First valid lane (valid-prefix layout => lane 0 of real blocks).
    start = jnp.argmax(v.astype(jnp.float32)).astype(jnp.int32)
    mind = jnp.where(v, d2_to(start), NEG)
    iot = lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    mind = jnp.where(iot == start, NEG, mind)
    mind_ref[...] = mind
    prev_ref[0] = start
    idx_ref[0, 0] = start

    def body(j, _):
        m = mind_ref[...]
        # Exhaustion contract (kernels/ref.py): unselected valid lanes
        # hold d2 >= 0 > NEG, so an all-pinned vector means k exceeds the
        # valid count — repeat the last valid selection.
        nxt = jnp.where(jnp.max(m) > NEG,
                        jnp.argmax(m).astype(jnp.int32), prev_ref[0])
        m = jnp.minimum(m, jnp.where(v, d2_to(nxt), NEG))
        m = jnp.where(iot == nxt, NEG, m)
        mind_ref[...] = m
        prev_ref[0] = nxt
        idx_ref[0, j] = nxt
        return 0

    if k > 1:
        lax.fori_loop(1, k, body, 0)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def fps_blocks(coords: jax.Array, vmask: jax.Array, *, k: int,
               interpret: bool = True) -> jax.Array:
    """coords (NB, 3, BS) f32, vmask (NB, 1, BS) {0,1} -> idx (NB, k) i32."""
    nb, _, bs = coords.shape
    kernel = functools.partial(_fps_kernel, k=k)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, 3, bs), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 1, bs), lambda b: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, k), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, k), jnp.int32),
        scratch_shapes=[pltpu.VMEM((1, bs), jnp.float32),
                        pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(coords.astype(jnp.float32), vmask.astype(jnp.float32))

"""Block-wise ball-query Pallas kernel — RSPU grouping mode (paper §V-C).

One grid step = one leaf: the centers tile (the leaf's FPS samples) and the
search window (the leaf's parent range, contiguous thanks to the DFT layout)
are both VMEM-resident; every center reuses the same window — the paper's
intra-block data reuse (7.6x memory-access reduction).

The distance matrix uses the expanded |a|^2+|b|^2-2ab form so the cross term
is a (KC,3)x(3,W) contraction; neighbor selection is repeated masked min
(the merge-sort top-k unit's TPU analogue).  The kernel also counts the
in-radius candidates per center (the ASIC's counter), so callers get
``cnt`` without a second pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.kernels.common import INF, argmin_extract, sqdist_rows


def _bq_kernel(c_ref, cmask_ref, w_ref, wmask_ref, idx_ref, d2_ref, cnt_ref,
               *, num: int, r2: float):
    c = c_ref[0]                    # (3, KC)
    w = w_ref[0]                    # (3, W)
    wm = wmask_ref[0] > 0           # (1, W)
    cm = cmask_ref[0] > 0           # (1, KC)
    d = sqdist_rows(c, w)           # (KC, W)
    d = jnp.where(wm, d, INF)
    in_r = (d <= r2) & wm
    cnt_ref[0] = jnp.where(cm[0], jnp.sum(in_r.astype(jnp.int32), axis=1), 0)
    idx, val = argmin_extract(d, num)
    idx_ref[0] = idx
    d2_ref[0] = val


@functools.partial(jax.jit,
                   static_argnames=("radius", "num", "interpret"))
def ball_query_blocks(centers: jax.Array, cmask: jax.Array, window: jax.Array,
                      wmask: jax.Array, *, radius: float, num: int,
                      interpret: bool = True):
    """centers (NB,3,KC), cmask (NB,1,KC), window (NB,3,W), wmask (NB,1,W)
    -> (idx (NB,KC,num) i32 local-to-window, d2 (NB,KC,num), cnt (NB,KC))."""
    nb, _, kc = centers.shape
    w = window.shape[-1]
    r2 = float(radius) ** 2
    kernel = functools.partial(_bq_kernel, num=num, r2=r2)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, 3, kc), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 1, kc), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 3, w), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 1, w), lambda b: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, kc, num), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, kc, num), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, kc), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, kc, num), jnp.int32),
            jax.ShapeDtypeStruct((nb, kc, num), jnp.float32),
            jax.ShapeDtypeStruct((nb, kc), jnp.int32),
        ],
        interpret=interpret,
    )(centers.astype(jnp.float32), cmask.astype(jnp.float32),
      window.astype(jnp.float32), wmask.astype(jnp.float32))

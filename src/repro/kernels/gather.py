"""Block-wise gathering Pallas kernel (paper BWGa, §IV-B / §V-B).

The ASIC insight: after Fractal, each gather unit only touches one parent
window, which fits on-chip — no global random access.  The TPU analogue:
the window's features are one VMEM tile per grid step, and the *random*
in-window gather becomes a one-hot (M, W) x (W, C) matmul on the MXU —
random access converted to dense compute, the canonical TPU trade.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _gather_kernel(feats_ref, idx_ref, out_ref):
    f = feats_ref[0]             # (W, C)
    idx = idx_ref[0]             # (1, M) i32
    w = f.shape[0]
    m = idx.shape[-1]
    iot = lax.broadcasted_iota(jnp.int32, (m, w), 1)
    onehot = (iot == idx[0][:, None]).astype(f.dtype)
    out_ref[0] = jnp.dot(onehot, f, preferred_element_type=f.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_blocks(window_feats: jax.Array, idx: jax.Array, *,
                  interpret: bool = True) -> jax.Array:
    """window_feats (NB, W, C), idx (NB, M) local-to-window
    -> (NB, M, C) gathered features."""
    nb, w, c = window_feats.shape
    m = idx.shape[-1]
    return pl.pallas_call(
        _gather_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, w, c), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 1, m), lambda b: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, m, c), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, m, c), window_feats.dtype),
        interpret=interpret,
    )(window_feats, idx.astype(jnp.int32)[:, None, :])

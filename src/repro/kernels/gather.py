"""Block-wise gathering Pallas kernel (paper BWGa, §IV-B / §V-B).

The ASIC insight: after Fractal, each gather unit only touches one parent
window, which fits on-chip — no global random access.  The TPU analogue:
the window's features are one VMEM tile per grid step, and the *random*
in-window gather becomes a one-hot (M, W) x (W, C) matmul on the MXU —
random access converted to dense compute, the canonical TPU trade.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _gather_kernel(feats_ref, idx_ref, out_ref):
    f = feats_ref[0]             # (W, C)
    idx = idx_ref[0]             # (1, M) i32
    w = f.shape[0]
    m = idx.shape[-1]
    iot = lax.broadcasted_iota(jnp.int32, (m, w), 1)
    onehot = (iot == idx[0][:, None]).astype(f.dtype)
    out_ref[0] = jnp.dot(onehot, f, preferred_element_type=f.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_blocks(window_feats: jax.Array, idx: jax.Array, *,
                  interpret: bool = True) -> jax.Array:
    """window_feats (NB, W, C), idx (NB, M) local-to-window
    -> (NB, M, C) gathered features.  Out-of-range idx (negative or >= W)
    matches no one-hot row and fetches zeros."""
    nb, w, c = window_feats.shape
    m = idx.shape[-1]
    return pl.pallas_call(
        _gather_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, w, c), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 1, m), lambda b: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, m, c), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, m, c), window_feats.dtype),
        interpret=interpret,
    )(window_feats, idx.astype(jnp.int32)[:, None, :])


def _scatter_add_kernel(g_ref, idx_ref, out_ref):
    g = g_ref[0]                 # (M, C) cotangent rows
    idx = idx_ref[0]             # (1, M) i32
    w = out_ref.shape[-2]
    m = g.shape[0]
    # Transpose of the forward's one-hot: (W, M) @ (M, C) on the MXU.
    # Out-of-range idx (including the -1 lane padding) matches no row and
    # contributes nothing — the scatter drops exactly what the gather
    # zero-filled.
    iot = lax.broadcasted_iota(jnp.int32, (w, m), 0)
    onehot_t = (iot == idx[0][None, :]).astype(g.dtype)
    out_ref[0] = jnp.dot(onehot_t, g, preferred_element_type=g.dtype)


@functools.partial(jax.jit, static_argnames=("w", "interpret"))
def scatter_add_blocks(g: jax.Array, idx: jax.Array, *, w: int,
                       interpret: bool = True) -> jax.Array:
    """gather_blocks' backward: g (NB, M, C) cotangents, idx (NB, M)
    local-to-window -> (NB, W, C) scatter-added window cotangents.

    The ASIC story holds in reverse: each block's backward touches only
    its own VMEM-resident window tile, and the random scatter-add becomes
    a dense (W, M) x (M, C) matmul — the forward's one-hot trick,
    transposed (docs/DESIGN.md §4)."""
    nb, m, c = g.shape
    return pl.pallas_call(
        _scatter_add_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, m, c), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 1, m), lambda b: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, w, c), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, w, c), g.dtype),
        interpret=interpret,
    )(g, idx.astype(jnp.int32)[:, None, :])

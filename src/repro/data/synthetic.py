"""Synthetic parametric point-cloud data (S3DIS/ModelNet stand-in).

Offline container: the paper's datasets are unavailable, so accuracy-trend
experiments (global ops vs BPPO, threshold sweeps — paper Figs. 14/17) run
on procedurally generated clouds with the *same comparison structure*.

The pipeline is **resumable**: batches are a pure function of
(seed, step) via counter-based RNG (fold_in), so a restart from a
checkpointed step reproduces the exact stream — part of the fault-tolerance
story (train/checkpoint.py stores the step only).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

NUM_SHAPES = 6  # sphere, cube, torus, cylinder, plane, helix


def _sphere(u, v, _):
    theta = 2 * jnp.pi * u
    phi = jnp.arccos(jnp.clip(2 * v - 1, -1, 1))
    return jnp.stack([jnp.sin(phi) * jnp.cos(theta),
                      jnp.sin(phi) * jnp.sin(theta),
                      jnp.cos(phi)], -1)


def _cube(u, v, w):
    face = jnp.floor(w * 6).astype(jnp.int32) % 6
    a = u * 2 - 1
    b = v * 2 - 1
    one = jnp.ones_like(a)
    faces = jnp.stack([
        jnp.stack([a, b, one], -1), jnp.stack([a, b, -one], -1),
        jnp.stack([a, one, b], -1), jnp.stack([a, -one, b], -1),
        jnp.stack([one, a, b], -1), jnp.stack([-one, a, b], -1)], 0)
    return jnp.take_along_axis(
        faces, face[None, :, None], axis=0)[0]


def _torus(u, v, _):
    theta, phi = 2 * jnp.pi * u, 2 * jnp.pi * v
    r, R = 0.3, 1.0
    return jnp.stack([(R + r * jnp.cos(phi)) * jnp.cos(theta),
                      (R + r * jnp.cos(phi)) * jnp.sin(theta),
                      r * jnp.sin(phi)], -1)


def _cylinder(u, v, _):
    theta = 2 * jnp.pi * u
    return jnp.stack([jnp.cos(theta), jnp.sin(theta), 2 * v - 1], -1)


def _plane(u, v, _):
    return jnp.stack([2 * u - 1, 2 * v - 1, jnp.zeros_like(u)], -1)


def _helix(u, v, _):
    t = 4 * jnp.pi * u
    return jnp.stack([jnp.cos(t) * (1 + 0.1 * v),
                      jnp.sin(t) * (1 + 0.1 * v),
                      (t / (2 * jnp.pi)) - 1], -1)


_SHAPES = (_sphere, _cube, _torus, _cylinder, _plane, _helix)


def _sample_shape(key, shape_id, n, noise=0.02):
    ku, kv, kw, kn, kr = jax.random.split(key, 5)
    u = jax.random.uniform(ku, (n,))
    v = jax.random.uniform(kv, (n,))
    w = jax.random.uniform(kw, (n,))
    pts = jax.lax.switch(shape_id, [
        functools.partial(f) for f in _SHAPES], u, v, w)
    pts = pts + noise * jax.random.normal(kn, (n, 3))
    # random rotation (z) + anisotropic scale: breaks axis alignment so the
    # partitioner cannot cheat.
    ang = jax.random.uniform(kr, (), minval=0, maxval=2 * jnp.pi)
    c, s = jnp.cos(ang), jnp.sin(ang)
    rot = jnp.array([[c, -s, 0.0], [s, c, 0.0], [0.0, 0.0, 1.0]])
    scale = jax.random.uniform(jax.random.fold_in(kr, 1), (3,),
                               minval=0.7, maxval=1.3)
    return (pts * scale) @ rot.T


def classification_batch(seed: int, step: int, batch: int, n: int):
    """Returns (points (B, n, 3), labels (B,)) — one shape per cloud."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)

    def one(k):
        kl, ks = jax.random.split(k)
        label = jax.random.randint(kl, (), 0, NUM_SHAPES)
        return _sample_shape(ks, label, n), label

    pts, labels = jax.vmap(one)(jax.random.split(key, batch))
    return pts, labels


def segmentation_batch(seed: int, step: int, batch: int, n: int,
                       parts: int = 3):
    """Scene = `parts` displaced shapes; per-point label = shape id."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step + (1 << 20))
    per = n // parts

    def one(k):
        ks = jax.random.split(k, parts)

        def piece(kk):
            kl, kp, kd = jax.random.split(kk, 3)
            label = jax.random.randint(kl, (), 0, NUM_SHAPES)
            pts = _sample_shape(kp, label, per)
            off = jax.random.uniform(kd, (3,), minval=-2.5, maxval=2.5)
            return pts + off, jnp.full((per,), label)

        ps, ls = jax.vmap(piece)(ks)
        pts = ps.reshape(-1, 3)
        lab = ls.reshape(-1)
        pad = n - pts.shape[0]
        if pad:
            pts = jnp.concatenate([pts, pts[:pad]])
            lab = jnp.concatenate([lab, lab[:pad]])
        return pts, lab

    pts, labels = jax.vmap(one)(jax.random.split(key, batch))
    return pts, labels


@dataclasses.dataclass
class DataState:
    """Resumable pipeline cursor (checkpointed alongside params)."""
    seed: int
    step: int

    def next_classification(self, batch, n):
        out = classification_batch(self.seed, self.step, batch, n)
        self.step += 1
        return out

    def next_segmentation(self, batch, n, parts=3):
        out = segmentation_batch(self.seed, self.step, batch, n, parts)
        self.step += 1
        return out

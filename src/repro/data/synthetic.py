"""Synthetic parametric point-cloud data (S3DIS/ModelNet stand-in).

Offline container: the paper's datasets are unavailable, so accuracy-trend
experiments (global ops vs BPPO, threshold sweeps — paper Figs. 14/17) run
on procedurally generated clouds with the *same comparison structure*.

The pipeline is **resumable**: batches are a pure function of
(seed, step) via counter-based RNG (fold_in), so a restart from a
checkpointed step reproduces the exact stream — part of the fault-tolerance
story (train/checkpoint.py stores the step only).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

NUM_SHAPES = 6  # sphere, cube, torus, cylinder, plane, helix


def _sphere(u, v, _):
    theta = 2 * jnp.pi * u
    phi = jnp.arccos(jnp.clip(2 * v - 1, -1, 1))
    return jnp.stack([jnp.sin(phi) * jnp.cos(theta),
                      jnp.sin(phi) * jnp.sin(theta),
                      jnp.cos(phi)], -1)


def _cube(u, v, w):
    face = jnp.floor(w * 6).astype(jnp.int32) % 6
    a = u * 2 - 1
    b = v * 2 - 1
    one = jnp.ones_like(a)
    faces = jnp.stack([
        jnp.stack([a, b, one], -1), jnp.stack([a, b, -one], -1),
        jnp.stack([a, one, b], -1), jnp.stack([a, -one, b], -1),
        jnp.stack([one, a, b], -1), jnp.stack([-one, a, b], -1)], 0)
    return jnp.take_along_axis(
        faces, face[None, :, None], axis=0)[0]


def _torus(u, v, _):
    theta, phi = 2 * jnp.pi * u, 2 * jnp.pi * v
    r, R = 0.3, 1.0
    return jnp.stack([(R + r * jnp.cos(phi)) * jnp.cos(theta),
                      (R + r * jnp.cos(phi)) * jnp.sin(theta),
                      r * jnp.sin(phi)], -1)


def _cylinder(u, v, _):
    theta = 2 * jnp.pi * u
    return jnp.stack([jnp.cos(theta), jnp.sin(theta), 2 * v - 1], -1)


def _plane(u, v, _):
    return jnp.stack([2 * u - 1, 2 * v - 1, jnp.zeros_like(u)], -1)


def _helix(u, v, _):
    t = 4 * jnp.pi * u
    return jnp.stack([jnp.cos(t) * (1 + 0.1 * v),
                      jnp.sin(t) * (1 + 0.1 * v),
                      (t / (2 * jnp.pi)) - 1], -1)


_SHAPES = (_sphere, _cube, _torus, _cylinder, _plane, _helix)


def _sample_shape(key, shape_id, n, noise=0.02):
    ku, kv, kw, kn, kr = jax.random.split(key, 5)
    u = jax.random.uniform(ku, (n,))
    v = jax.random.uniform(kv, (n,))
    w = jax.random.uniform(kw, (n,))
    pts = jax.lax.switch(shape_id, [
        functools.partial(f) for f in _SHAPES], u, v, w)
    pts = pts + noise * jax.random.normal(kn, (n, 3))
    # random rotation (z) + anisotropic scale: breaks axis alignment so the
    # partitioner cannot cheat.
    ang = jax.random.uniform(kr, (), minval=0, maxval=2 * jnp.pi)
    c, s = jnp.cos(ang), jnp.sin(ang)
    rot = jnp.array([[c, -s, 0.0], [s, c, 0.0], [0.0, 0.0, 1.0]])
    scale = jax.random.uniform(jax.random.fold_in(kr, 1), (3,),
                               minval=0.7, maxval=1.3)
    return (pts * scale) @ rot.T


def classification_batch(seed: int, step: int, batch: int, n: int):
    """Returns (points (B, n, 3), labels (B,)) — one shape per cloud."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)

    def one(k):
        kl, ks = jax.random.split(k)
        label = jax.random.randint(kl, (), 0, NUM_SHAPES)
        return _sample_shape(ks, label, n), label

    pts, labels = jax.vmap(one)(jax.random.split(key, batch))
    return pts, labels


def segmentation_batch(seed: int, step: int, batch: int, n: int,
                       parts: int = 3):
    """Scene = `parts` displaced shapes; per-point label = shape id."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step + (1 << 20))
    per = n // parts

    def one(k):
        ks = jax.random.split(k, parts)

        def piece(kk):
            kl, kp, kd = jax.random.split(kk, 3)
            label = jax.random.randint(kl, (), 0, NUM_SHAPES)
            pts = _sample_shape(kp, label, per)
            off = jax.random.uniform(kd, (3,), minval=-2.5, maxval=2.5)
            return pts + off, jnp.full((per,), label)

        ps, ls = jax.vmap(piece)(ks)
        pts = ps.reshape(-1, 3)
        lab = ls.reshape(-1)
        pad = n - pts.shape[0]
        if pad:
            pts = jnp.concatenate([pts, pts[:pad]])
            lab = jnp.concatenate([lab, lab[:pad]])
        return pts, lab

    pts, labels = jax.vmap(one)(jax.random.split(key, batch))
    return pts, labels


# ---------------------------------------------------------------------------
# Room-scale scenes (repro.scene workload): chunked, counter-based RNG.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("m",))
def _scene_chunk(pkey, tkey, shape_id, start, *, m: int, noise: float,
                 extent: float):
    """One ``m``-point chunk of one object, already posed in the scene.

    Per-point randomness is keyed ``fold_in(pkey, point_index)`` — a pure
    counter — so the stream is independent of how generation is chunked;
    the object's pose (rotation/scale/offset) comes from ``tkey`` and is
    identical for every chunk of the object.
    """
    def one(i):
        k = jax.random.fold_in(pkey, i)
        uvw = jax.random.uniform(jax.random.fold_in(k, 0), (3,))
        nz = jax.random.normal(jax.random.fold_in(k, 1), (3,))
        return uvw, nz

    uvw, nz = jax.vmap(one)(start + jnp.arange(m, dtype=jnp.int32))
    pts = jax.lax.switch(shape_id, list(_SHAPES),
                         uvw[:, 0], uvw[:, 1], uvw[:, 2])
    pts = pts + noise * nz
    ka, ks, kd = jax.random.split(tkey, 3)
    ang = jax.random.uniform(ka, (), minval=0, maxval=2 * jnp.pi)
    c, s = jnp.cos(ang), jnp.sin(ang)
    rot = jnp.array([[c, -s, 0.0], [s, c, 0.0], [0.0, 0.0, 1.0]])
    scale = jax.random.uniform(ks, (3,), minval=0.5, maxval=1.2)
    off = jax.random.uniform(kd, (3,), minval=-extent, maxval=extent)
    off = off * jnp.array([1.0, 1.0, 0.35])  # rooms are flat in z
    return (pts * scale) @ rot.T + off


def scene(seed: int, n: int, *, objects: int | None = None,
          chunk: int = 65536, noise: float = 0.02, extent: float = 6.0):
    """A multi-object scene: (points (n, 3) f32, labels (n,) i32) numpy.

    The repro.scene workload generator — S3DIS-shaped occupancy (many
    posed shapes scattered over a flat room) at any ``n`` up to millions
    of points.  Unlike the batch generators above, points are produced
    ``chunk`` at a time and accumulated on the host, so peak *device*
    memory is O(chunk) — a 1M-point scene never materializes an
    (n, NUM_SHAPES, 3)-shaped intermediate (the cube generator alone
    stacks 6 candidate faces per point).  Per-point RNG is counter-based
    (``fold_in(key, point_index)``), so the stream depends only on
    ``(seed, n, objects)`` — not on ``chunk`` — and any slice of the
    scene can be regenerated independently.

    Labels are the shape id of the object each point was sampled from
    (the segmentation target).
    """
    if n <= 0:
        raise ValueError(f"need n > 0, got {n}")
    if objects is None:
        objects = max(2, n // 2048)
    elif objects <= 0:
        raise ValueError(f"need objects > 0, got {objects}")
    objects = min(objects, n)
    base = jax.random.PRNGKey(seed)
    okeys = jax.vmap(lambda o: jax.random.fold_in(base, o))(
        jnp.arange(objects))
    sids = np.asarray(jax.vmap(
        lambda k: jax.random.randint(k, (), 0, NUM_SHAPES))(okeys))

    points = np.empty((n, 3), np.float32)
    labels = np.empty((n,), np.int32)
    per, extra = divmod(n, objects)
    pos = 0
    for o in range(objects):
        count = per + (1 if o < extra else 0)
        if count == 0:
            continue
        pkey, tkey = jax.random.split(okeys[o])
        sid = int(sids[o])
        done = 0
        while done < count:
            m = min(chunk, count - done)
            pts = _scene_chunk(pkey, tkey, sids[o], jnp.int32(done), m=m,
                               noise=noise, extent=extent)
            points[pos:pos + m] = np.asarray(pts)
            pos += m
            done += m
        labels[pos - count:pos] = sid
    return points, labels


@dataclasses.dataclass
class DataState:
    """Resumable pipeline cursor (checkpointed alongside params)."""
    seed: int
    step: int

    def next_classification(self, batch, n):
        out = classification_batch(self.seed, self.step, batch, n)
        self.step += 1
        return out

    def next_segmentation(self, batch, n, parts=3):
        out = segmentation_batch(self.seed, self.step, batch, n, parts)
        self.step += 1
        return out

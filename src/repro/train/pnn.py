"""PNN fine-tuning: ``jax.value_and_grad`` through the full BPPO pipeline
with either point-op backend.

With the execute-phase VJPs in place (kernels/vjp.py, docs/DESIGN.md §4)
``PNNConfig(impl="pallas")`` is valid under ``jax.grad`` — the kernels run
in the backward pass too (gather's transposed one-hot scatter-add; the
index producers contribute zero cotangents), so training no longer falls
back to the XLA oracle.  The loop reuses the repo's training
infrastructure: ``train/optimizer.py`` (AdamW + clipping),
``train/checkpoint.py`` + ``train/loop.py`` (restore/resume, straggler
monitor), ``data/synthetic.py`` (resumable counter-based batches), and
shards like ``launch/train.py``: clouds -> the ``batch`` logical axis
(``dist.logical.fit_specs``-fitted so non-dividing batch sizes drop),
fractal leaves -> ``model`` via the ``lc`` constraints already inside
``core/bppo.py``.

CLI (the CI train-smoke leg)::

  PYTHONPATH=src python -m repro.train.pnn --preset pointnet2_cls \
      --steps 4 --impl pallas --mesh auto
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.data import synthetic
from repro.dist import elastic, logical
from repro.kernels import ops as kops
from repro.models import pnn
from repro.train import loop as loop_lib
from repro.train import optimizer as opt_lib

PRESETS = {
    "pointnet2_cls": pnn.pointnet2_cls,
    "pointnext_cls": pnn.pointnext_cls,
    "pointnet2_seg": pnn.pointnet2_seg,
    "pointnext_seg": pnn.pointnext_seg,
    "pointvector_seg": pnn.pointvector_seg,
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Fine-tune knobs: model preset + data shape + dispatch + loop."""

    preset: str = "pointnet2_cls"
    n_points: int = 192
    th: int = 32
    point_ops: str = "bppo"          # bppo | global
    impl: str | None = None          # xla | pallas | None ($REPRO_POINT_IMPL)
    batch: int = 8
    steps: int = 20
    lr: float = 3e-3
    weight_decay: float = 0.0
    seed: int = 0
    mesh: str = "none"               # none | auto (elastic host mesh)
    model_axis: int = 2
    leaf_chunk: int | None = None
    ckpt_dir: str = ""
    ckpt_every: int = 50
    grad_compression: str = "none"   # none | bf16 | int8


def model_config(cfg: TrainConfig) -> pnn.PNNConfig:
    # Same default chain as every other entrypoint: explicit arg >
    # $REPRO_POINT_IMPL > the xla oracle.
    mcfg = PRESETS[cfg.preset](n=cfg.n_points, point_ops=cfg.point_ops,
                               th=cfg.th,
                               impl=kops.resolve_impl(cfg.impl,
                                                      default="xla"))
    return dataclasses.replace(mcfg, leaf_chunk=cfg.leaf_chunk)


def loss_fn(params, mcfg: pnn.PNNConfig, batch):
    """Masked cross-entropy over a batch dict {points, labels[, valid]}.

    Returns (loss, aux) with aux = {"acc": ...} so the step metrics carry
    a trainability signal alongside the loss."""
    pts = logical.lc(batch["points"], "batch", "points", None)
    labels = batch["labels"]
    valid = batch.get("valid")
    if valid is None:
        valid = jnp.ones(pts.shape[:2], bool)
    logits = jax.vmap(lambda c, v: pnn.apply(params, mcfg, c, valid=v))(
        pts, valid)
    ll = jax.nn.log_softmax(logits)
    if mcfg.task == "cls":
        picked = jnp.take_along_axis(ll, labels[:, None], axis=-1)
        loss = -jnp.mean(picked)
        acc = jnp.mean(jnp.argmax(logits, -1) == labels)
    else:
        picked = jnp.take_along_axis(ll, labels[..., None], axis=-1)[..., 0]
        denom = jnp.maximum(jnp.sum(valid), 1)
        loss = -jnp.sum(jnp.where(valid, picked, 0.0)) / denom
        hit = (jnp.argmax(logits, -1) == labels) & valid
        acc = jnp.sum(hit) / denom
    return loss, {"acc": acc}


def train_step_fn(mcfg: pnn.PNNConfig, opt_cfg: opt_lib.OptConfig):
    """The raw (unjitted) fine-tune step: value_and_grad + AdamW update.

    Split out so callers that own their own jit (the dry-run train cell
    lowers it with explicit in_shardings) stay in lockstep with the step
    the trainer actually runs."""
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(params, opt_state, batch):
        (loss, aux), grads = grad_fn(params, mcfg, batch)
        params, opt_state, om = opt_lib.update(opt_cfg, grads, opt_state,
                                               params)
        return params, opt_state, {"loss": loss, **aux, **om}

    return step


def make_train_step(mcfg: pnn.PNNConfig, opt_cfg: opt_lib.OptConfig):
    """One jitted AdamW step; ``return_grads=True`` hands raw grads back
    for the loop's gradient-compression / error-feedback path."""
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    step = jax.jit(train_step_fn(mcfg, opt_cfg))

    @jax.jit
    def grads_only(params, batch):
        (loss, aux), grads = grad_fn(params, mcfg, batch)
        return grads, {"loss": loss, **aux}

    def train_step(params, opt_state, batch, return_grads=False):
        if return_grads:
            return grads_only(params, batch)
        return step(params, opt_state, batch)

    return train_step


def fit(cfg: TrainConfig, params=None, log=print):
    """Run the fine-tune loop; returns (params, opt_state, info).

    ``info["history"]`` carries per-step loss (the generic loop records
    {step, dt, loss, straggler}); with ``ckpt_dir`` set the loop restores
    the latest step and resumes (the synthetic batch stream is a pure
    function of (seed, step), so a restart reproduces the exact
    stream)."""
    mcfg = model_config(cfg)
    mesh = (elastic.make_mesh(model_axis=cfg.model_axis)
            if cfg.mesh == "auto" else None)
    rules = logical.RULES_V0
    if mesh is not None:
        log(f"[train.pnn] mesh "
            f"{dict(zip(mesh.axis_names, mesh.devices.shape))} "
            f"({mesh.devices.size} devices), impl={mcfg.impl}")

    def init_params():
        p = pnn.init(jax.random.PRNGKey(cfg.seed), mcfg)
        if mesh is not None:
            # PNN params are small: replicate; the point-op leaves shard
            # over "model" via bppo's lc constraints inside the step.
            p = jax.device_put(p, jax.tree.map(
                lambda _: NamedSharding(mesh, P()), p))
        return p

    def next_batch(step):
        if mcfg.task == "cls":
            pts, labels = synthetic.classification_batch(
                cfg.seed + 11, step, cfg.batch, cfg.n_points)
        else:
            pts, labels = synthetic.segmentation_batch(
                cfg.seed + 11, step, cfg.batch, cfg.n_points)
        batch = {"points": pts, "labels": labels}
        if mesh is None:
            return batch
        with logical.logical_rules(mesh, rules):
            sh = {"points": NamedSharding(
                      mesh, logical.spec(("batch", "points", None))),
                  "labels": NamedSharding(
                      mesh, logical.spec(("batch",) + (("points",)
                                         if mcfg.task == "seg" else ())))}
        return jax.device_put(batch, logical.fit_specs(sh, batch, mesh))

    opt_cfg = opt_lib.OptConfig(lr=cfg.lr, warmup=0,
                                total_steps=max(cfg.steps, 1),
                                weight_decay=cfg.weight_decay)
    base = make_train_step(mcfg, opt_cfg)

    def train_step(params, opt_state, batch, return_grads=False):
        if mesh is None:
            return base(params, opt_state, batch, return_grads)
        with logical.logical_rules(mesh, rules):
            return base(params, opt_state, batch, return_grads)

    loop_cfg = loop_lib.LoopConfig(
        total_steps=cfg.steps, ckpt_dir=cfg.ckpt_dir,
        ckpt_every=cfg.ckpt_every, log_every=max(1, cfg.steps // 5),
        grad_compression=cfg.grad_compression, seed=cfg.seed)
    return loop_lib.run(loop_cfg, init_params=init_params,
                        train_step=train_step, next_batch=next_batch,
                        opt_cfg=opt_cfg, params=params, log=log)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="pointnet2_cls",
                    choices=sorted(PRESETS))
    ap.add_argument("--n", type=int, default=192)
    ap.add_argument("--th", type=int, default=32)
    ap.add_argument("--point-ops", default="bppo",
                    choices=["bppo", "global"])
    ap.add_argument("--impl", default=None, choices=["xla", "pallas"],
                    help="point-op execute backend (default: "
                         "$REPRO_POINT_IMPL or xla) — both differentiate")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default="none", choices=["none", "auto"])
    ap.add_argument("--leaf-chunk", type=int, default=None)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--compression", default="none",
                    choices=["none", "bf16", "int8"])
    args = ap.parse_args(argv)

    cfg = TrainConfig(preset=args.preset, n_points=args.n, th=args.th,
                      point_ops=args.point_ops, impl=args.impl,
                      batch=args.batch, steps=args.steps, lr=args.lr,
                      seed=args.seed, mesh=args.mesh,
                      leaf_chunk=args.leaf_chunk, ckpt_dir=args.ckpt,
                      grad_compression=args.compression)
    _, _, info = fit(cfg)
    h = info["history"]
    if h:
        print(f"[train.pnn] done: loss {h[0]['loss']:.4f} -> "
              f"{h[-1]['loss']:.4f} over {len(h)} steps; "
              f"{info['monitor']}")
    else:
        print("[train.pnn] nothing to do: checkpoint already at "
              f"step >= {args.steps}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Step-scoped, async, reshardable checkpointing.

Arrays are saved with their *logical* (unsharded) shapes keyed by tree
paths, so a checkpoint written on any mesh restores onto any other mesh
(elastic scaling): restore takes target shardings and device_puts shard-
by-shard.  Writes go to a tmp dir + atomic rename; a manifest records the
step and data-pipeline cursor, and ``latest_step`` drives crash-restart.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np

_SEP = "/"


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p):
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return str(p.idx)
    if isinstance(p, jax.tree_util.GetAttrKey):
        return str(p.name)
    return str(p)


def save(ckpt_dir: str, step: int, tree, extra: dict | None = None,
         keep: int = 3):
    """Synchronous save (see AsyncCheckpointer for the async wrapper)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f".tmp-{step}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)

    def to_np(v):
        a = np.asarray(v)
        # npz cannot round-trip ml_dtypes (bf16 etc.); store as f32
        # (lossless for bf16) and let restore cast back.
        if a.dtype.kind not in "fiub?" or a.dtype.itemsize == 0:
            a = a.astype(np.float32)
        return a

    arrays = {k: to_np(v) for k, v in flat.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    # Manifest timestamps are read by other processes/hosts (restore
    # tooling, GC-by-age), so wall clock is the correct domain here.
    manifest = {"step": step, "time": time.time(),  # repolint: disable=CLK003
                "extra": extra or {}, "keys": sorted(arrays)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir, keep):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_"):
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(ckpt_dir: str):
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, like_tree, shardings=None):
    """Restore into the structure of ``like_tree``; optionally device_put
    with new shardings (mesh-independent resharding)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as data:
        flat = {k: data[k] for k in data.files}
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    leaves_paths = jax.tree_util.tree_flatten_with_path(like_tree)[0]
    out_leaves = []
    for p, like in leaves_paths:
        key = _SEP.join(_path_str(x) for x in p)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != {like.shape}")
        out_leaves.append(np.asarray(jax.numpy.asarray(arr, like.dtype)))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like_tree), out_leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, sh: jax.device_put(x, sh), tree, shardings)
    return tree, manifest


class AsyncCheckpointer:
    """Fire-and-forget saves on a background thread (training never blocks
    on disk); ``wait()`` drains before exit.  Arrays are fetched to host
    before handing off, so the step's buffers cannot be mutated under us."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread = None
        self.last_saved = None

    def save(self, step: int, tree, extra=None):
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self.wait()
        self._thread = threading.Thread(
            target=self._run, args=(step, host_tree, extra), daemon=True)
        self._thread.start()

    def _run(self, step, tree, extra):
        save(self.ckpt_dir, step, tree, extra, keep=self.keep)
        self.last_saved = step

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

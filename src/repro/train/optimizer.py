"""AdamW with cosine schedule, global-norm clipping, sharded states.

Optimizer moments mirror the parameter tree (and its logical axes), so
ZeRO-1 style state sharding falls out of the same spec machinery.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    min_lr_frac: float = 0.1
    warmup: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup, 1), 1.0)
    t = jnp.clip((step - cfg.warmup) /
                 jnp.maximum(cfg.total_steps - cfg.warmup, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * \
        (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init(params):
    zeros = lambda p: jax.tree.map(
        lambda x: jnp.zeros_like(x, dtype=jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def init_axes(params_axes):
    return {"m": params_axes, "v": params_axes, "step": None}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: OptConfig, grads, opt_state, params):
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, opt_state["m"], opt_state["v"], params)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, \
        {"lr": lr, "grad_norm": gnorm}

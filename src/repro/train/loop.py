"""Fault-tolerant training loop: checkpoint/restart, straggler monitoring,
resumable data, optional gradient compression with error feedback."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro.dist import compression
from repro.train import checkpoint as ckpt_lib
from repro.train import optimizer as opt_lib
from repro.train.monitor import StepMonitor


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_dir: str = ""
    ckpt_every: int = 50
    log_every: int = 10
    keep: int = 3
    grad_compression: str = "none"   # none | bf16 | int8
    seed: int = 0


def run(loop_cfg: LoopConfig, *, init_params: Callable,
        train_step: Callable, next_batch: Callable, opt_cfg=None,
        params=None, log: Callable = print, fail_at: int | None = None):
    """Generic loop: restores the latest checkpoint if present, trains to
    total_steps, checkpoints asynchronously, records stragglers.

    ``fail_at`` injects a crash (fault-tolerance tests).
    Returns (params, opt_state, history).
    """
    opt_cfg = opt_cfg or opt_lib.OptConfig(total_steps=loop_cfg.total_steps)
    if params is None:
        params = init_params()
    opt_state = opt_lib.init(params)
    start_step = 0
    saver = ckpt_lib.AsyncCheckpointer(loop_cfg.ckpt_dir, loop_cfg.keep) \
        if loop_cfg.ckpt_dir else None
    residual = (compression.init_residual(params)
                if loop_cfg.grad_compression != "none" else None)

    if saver and (last := ckpt_lib.latest_step(loop_cfg.ckpt_dir)) is not None:
        state = {"params": params, "opt": opt_state}
        state, manifest = ckpt_lib.restore(loop_cfg.ckpt_dir, last, state)
        params, opt_state = state["params"], state["opt"]
        start_step = manifest["extra"].get("next_step", last)
        log(f"[loop] restored step {last}, resuming at {start_step}")

    monitor = StepMonitor()
    history = []
    for step in range(start_step, loop_cfg.total_steps):
        if fail_at is not None and step == fail_at:
            saver and saver.wait()
            raise RuntimeError(f"injected failure at step {step}")
        batch = next_batch(step)
        t0 = time.monotonic()
        if residual is not None:
            # grad-compression path: train_step returns grads for EF wrap
            grads, metrics = train_step(params, opt_state, batch,
                                        return_grads=True)
            grads, residual = compression.apply_error_feedback(
                grads, residual, loop_cfg.grad_compression,
                jax.random.fold_in(jax.random.PRNGKey(loop_cfg.seed), step))
            params, opt_state, om = opt_lib.update(opt_cfg, grads,
                                                   opt_state, params)
            metrics = {**metrics, **om}
        else:
            params, opt_state, metrics = train_step(params, opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.monotonic() - t0
        straggler = monitor.record(step, dt)
        history.append({"step": step, "dt": dt,
                        "loss": float(metrics["loss"]),
                        "straggler": straggler})
        if step % loop_cfg.log_every == 0:
            log(f"[loop] step {step} loss {float(metrics['loss']):.4f} "
                f"({dt*1e3:.0f} ms{' STRAGGLER' if straggler else ''})")
        if saver and step and step % loop_cfg.ckpt_every == 0:
            saver.save(step, {"params": params, "opt": opt_state},
                       extra={"next_step": step + 1})
    if saver:
        saver.save(loop_cfg.total_steps,
                   {"params": params, "opt": opt_state},
                   extra={"next_step": loop_cfg.total_steps})
        saver.wait()
    return params, opt_state, {"history": history,
                               "monitor": monitor.summary()}

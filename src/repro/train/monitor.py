"""Step-time monitoring + straggler detection.

At 1000+ nodes, slow steps are usually one slow host.  The monitor keeps an
EWMA/variance of step times and flags outliers (z-score) — the launcher's
hook point for straggler mitigation (re-dispatch, drop-host, or alert).
A ``HeartbeatFile`` gives the external supervisor a liveness signal; on a
real cluster this is the per-host file a watchdog scrapes.
"""
from __future__ import annotations

import json
import os
import time


class StepMonitor:
    def __init__(self, alpha: float = 0.1, z_thresh: float = 4.0,
                 warmup: int = 5):
        self.alpha = alpha
        self.z = z_thresh
        self.warmup = warmup
        self.mean = None
        self.var = 0.0
        self.count = 0
        self.stragglers = []

    def record(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        self.count += 1
        if self.mean is None:
            self.mean = dt
            return False
        is_straggler = False
        if self.count > self.warmup:
            sd = max(self.var ** 0.5, 1e-6, 0.05 * self.mean)
            if (dt - self.mean) / sd > self.z:
                is_straggler = True
                self.stragglers.append((step, dt, self.mean))
        # EWMA update (skip straggler samples so they don't poison the mean)
        if not is_straggler:
            d = dt - self.mean
            self.mean += self.alpha * d
            self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        return is_straggler

    def summary(self):
        return {"mean_s": self.mean, "std_s": self.var ** 0.5,
                "steps": self.count, "stragglers": len(self.stragglers)}


class HeartbeatFile:
    """Liveness file for an external watchdog.  The stamped time must be
    *wall* clock (the watchdog is a different process, so a monotonic
    reading would be meaningless to it) — but it enters through an
    injectable ``clock`` so tests and replayed traces stay deterministic,
    the same discipline ServeEngine uses (docs/DESIGN.md §11)."""

    def __init__(self, path: str, every: float = 10.0, clock=time.time):
        self.path = path
        self.every = every
        self._clock = clock
        self._last = 0.0

    def beat(self, step: int, payload=None):
        now = self._clock()
        if now - self._last < self.every:
            return
        self._last = now
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, "time": now,
                       "payload": payload or {}}, f)
        os.replace(tmp, self.path)

    @staticmethod
    def is_alive(path: str, timeout: float = 60.0,
                 clock=time.time) -> bool:
        try:
            with open(path) as f:
                data = json.load(f)
            return clock() - data["time"] < timeout
        except (OSError, ValueError, KeyError):
            return False

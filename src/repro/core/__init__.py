"""FractalCloud core: Fractal partitioning + Block-Parallel Point Ops."""
from repro.core import bppo, fractal, ref
from repro.core.fractal import (FRACTAL, KDTREE, OCTREE, STRATEGIES, UNIFORM,
                                FractalOverflowError, FractalOverflowWarning,
                                FractalPartition, check_overflow,
                                default_depth, leaf_view, max_leaves,
                                partition, window_view)
from repro.core.bppo import (BWNeighbors, BWSamples, blockwise_ball_query,
                             blockwise_fps, blockwise_interpolate,
                             blockwise_knn, gather)

__all__ = [
    "bppo", "fractal", "ref", "FRACTAL", "KDTREE", "OCTREE", "UNIFORM",
    "STRATEGIES", "FractalOverflowError", "FractalOverflowWarning",
    "FractalPartition", "check_overflow", "default_depth", "max_leaves",
    "partition", "leaf_view", "window_view", "BWSamples", "BWNeighbors",
    "blockwise_fps", "blockwise_ball_query", "blockwise_knn",
    "blockwise_interpolate", "gather",
]

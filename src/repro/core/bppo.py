"""Block-Parallel Point Operations (BPPO) — paper §IV-B.

Every global point op is localized to the Fractal block structure:

* block-wise FPS       — FPS runs independently per leaf with one *fixed
                         sampling rate* (no per-block hyper-parameters);
* block-wise ball query / 3-NN interpolation — the search space of a center
                         in a leaf is the leaf's *immediate parent* range
                         (depth<=1: the leaf itself), a contiguous window in
                         the DFT layout;
* block-wise gathering — feature fetches confined to the same windows.

All ops work in the *permuted (DFT) frame*: indices index the sorted arrays
(``part.coords``); map back with ``part.perm[idx]``.  Everything is
static-shape and vmap/pjit-friendly; leaves are the unit of parallelism —
the same axis the launcher shards across chips.

Each op is split into a *plan* phase (window/quota/compaction index math,
pure jnp here) and an *execute* phase (the distance / argmax / top-k inner
loops), which dispatches through ``kernels/ops.py``: ``impl="xla"`` runs the
jnp oracle (kernels/ref.py), ``impl="pallas"`` the TPU kernels
(interpret=True off-TPU).  ``impl=None`` resolves from
``$REPRO_POINT_IMPL`` (default ``"xla"``).  Both backends are trainable:
the execute ops carry custom VJPs (kernels/vjp.py) — gather differentiates
in its features, the index producers stop gradients — so ``jax.grad``
through any bppo op is valid at either impl.  See docs/DESIGN.md §4.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.fractal import FractalPartition, leaf_from, leaf_view, \
    subtree_slot_range, window_from
from repro.dist.logical import lc
from repro.kernels import ops as kops

Array = jax.Array
_INF = jnp.float32(3.0e38)


def _resolve(impl):
    # bppo ops default to the jnp path (differentiable, fast on CPU); the
    # kernel layer's own default stays "pallas".
    return kops.resolve_impl(impl, default="xla")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BWSamples:
    """Result of block-wise FPS (both per-leaf and compacted views)."""

    # Per-leaf (uncompacted) view; kbm = max samples per leaf.
    local_idx: Array   # (ML, kbm) int32 in-block index of each sample
    block_mask: Array  # (ML, kbm) bool  sample slot j < quota[i]
    gidx: Array        # (ML, kbm) int32 index into the sorted arrays
    quota: Array       # (ML,) int32 round(rate * leaf_vsize)
    cum_quota: Array   # (ML+1,) int32 exclusive prefix of quota
    # Compacted view (k_out static slots).
    idx: Array         # (k_out,) int32 into sorted arrays
    valid: Array       # (k_out,) bool
    coords: Array      # (k_out, 3)
    leaf: Array        # (k_out,) int32 leaf id of each sample
    total: Array       # () int32 sum of quotas (may exceed k_out; truncated)

    @property
    def k_out(self) -> int:
        return self.idx.shape[0]


def blockwise_fps(part: FractalPartition, *, rate: float, k_out: int,
                  bs: int, kbm: int | None = None,
                  impl: str | None = None) -> BWSamples:
    """Block-wise sampling (paper BWS): fixed-rate FPS per leaf, aggregated.

    Plan: leaf views + quotas + leaf-major compaction.  Execute: the masked
    FPS loop itself (the paper's RSPU sampling mode; the window-check skip
    becomes masking, visited points pinned to -inf — docs/DESIGN.md §2) runs per
    leaf via ``kernels.ops.fps_blocks``.
    """
    impl = _resolve(impl)
    if kbm is None:
        kbm = max(1, int(round(rate * bs)) + 1)
    kbm = min(kbm, bs)
    pts, mask, _ = leaf_view(part, part.coords, bs)        # (ML, bs, 3)
    pts = lc(pts, "blocks", None, None)                    # leaves -> chips
    mask = lc(mask, "blocks", None)
    quota = jnp.round(rate * part.leaf_vsize).astype(jnp.int32)
    quota = jnp.where(part.is_leaf, jnp.minimum(quota, kbm), 0)

    local = kops.fps_blocks(pts, mask, k=kbm, impl=impl)
    j = jnp.arange(kbm, dtype=jnp.int32)[None, :]
    bmask = (j < quota[:, None])
    gidx = jnp.clip(part.leaf_start[:, None] + local, 0, part.n - 1)

    cum = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(quota)])
    pos = jnp.where(bmask, cum[:-1, None] + j, k_out)      # k_out => dropped
    total = cum[-1]

    ml = quota.shape[0]
    leaf_ids = jnp.broadcast_to(jnp.arange(ml, dtype=jnp.int32)[:, None],
                                (ml, kbm))
    flat_pos = pos.reshape(-1)
    idx_c = jnp.zeros((k_out,), jnp.int32).at[flat_pos].set(
        gidx.reshape(-1), mode="drop")
    leaf_c = jnp.zeros((k_out,), jnp.int32).at[flat_pos].set(
        leaf_ids.reshape(-1), mode="drop")
    valid_c = jnp.arange(k_out) < jnp.minimum(total, k_out)
    coords_c = part.coords[idx_c] * valid_c[:, None]
    return BWSamples(local_idx=local, block_mask=bmask, gidx=gidx,
                     quota=quota, cum_quota=cum, idx=idx_c, valid=valid_c,
                     coords=coords_c, leaf=leaf_c, total=total)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BWNeighbors:
    """Block-wise neighbor-search result, aligned with BWSamples compaction."""

    idx: Array    # (k_out, num) int32 into sorted arrays
    mask: Array   # (k_out, num) bool in-radius (ball query) / valid (knn)
    cnt: Array    # (k_out,) int32 true neighbor count
    d2: Array     # (k_out, num) squared distances


def _window_to_global(widx: Array, lidx: Array) -> Array:
    """Map local-to-window neighbor indices to sorted-array indices."""
    return jnp.take_along_axis(
        jnp.broadcast_to(widx[:, None, :], lidx.shape[:2] + widx.shape[1:]),
        lidx, axis=-1)


def _neighbor_slices(part: FractalPartition, samp: BWSamples):
    """Per-leaf slice arrays the neighbor plans chunk over."""
    return (part.leaf_start, part.leaf_vsize, part.parent_start,
            part.parent_vsize, part.is_leaf,
            samp.gidx, samp.block_mask)


def _chunked_slices(sl, slice_fn, chunk):
    """Run a per-leaf-slice plan+execute body, whole or chunk at a time.

    With ``chunk``, windows are *built inside* each lax.map step, so the
    live footprint is one chunk's (chunk, w, 3) window tiles plus the
    kernel's (chunk, kbm, w) distance tile — not the full-ML plan tensors.
    Padded slice rows carry zeroed starts/masks and are sliced off."""
    if chunk is None:
        return slice_fn(sl)
    chunks, ml = kops.leaf_chunks(sl, chunk)
    out = jax.lax.map(slice_fn, chunks)
    return jax.tree.map(lambda a: a.reshape(-1, *a.shape[2:])[:ml], out)


def _bq_slice(part, sl, *, r2, radius, num, w, impl):
    ls, lv, ps, pv, il, gidx, bmask = sl
    win, wmask, widx = window_from(ls, lv, ps, pv, il, part.coords,
                                   part.valid, w)
    win = lc(win, "blocks", None, None)
    centers = lc(part.coords[gidx], "blocks", None, None)
    lidx, nd2, cnt = kops.ball_query_blocks(centers, bmask, win, wmask,
                                            radius=radius, num=num,
                                            impl=impl)
    nd2 = jnp.maximum(nd2, 0.0)  # expanded-form sqdist can cancel below 0
    in_r = (nd2 <= r2) & bmask[..., None]
    # Pad empty slots with the nearest neighbor (ref.py convention).
    lidx = jnp.where(in_r, lidx, lidx[..., :1])
    return _window_to_global(widx, lidx), in_r, cnt, nd2


def blockwise_ball_query(part: FractalPartition, samp: BWSamples, *,
                         radius: float, num: int, w: int,
                         chunk: int | None = None,
                         impl: str | None = None) -> BWNeighbors:
    """Block-wise grouping (paper BWG): centers search their parent window.

    Plan: window/center tiles + index translation + compaction.  Execute:
    distance matrix + in-radius top-k via ``kernels.ops.ball_query_blocks``.
    ``chunk`` processes that many leaves per lax.map step — window tiles
    and the (chunk, kbm, w) distance tile replace the full-ML tensors."""
    impl = _resolve(impl)
    r2 = jnp.float32(radius) ** 2
    out = _chunked_slices(
        _neighbor_slices(part, samp),
        lambda s: _bq_slice(part, s, r2=r2, radius=radius, num=num, w=w,
                            impl=impl), chunk)
    g, in_r, cnt, nd2 = out
    return _compact_neighbors(samp, g, in_r, cnt, nd2, num)


def _knn_slice(part, sl, *, k, w, impl):
    ls, lv, ps, pv, il, gidx, bmask = sl
    win, wmask, widx = window_from(ls, lv, ps, pv, il, part.coords,
                                   part.valid, w)
    win = lc(win, "blocks", None, None)
    centers = lc(part.coords[gidx], "blocks", None, None)
    lidx, nd2 = kops.knn_blocks(centers, win, wmask, k=k, impl=impl)
    ok = (nd2 < _INF) & bmask[..., None]
    nd2 = jnp.maximum(nd2, 0.0)
    cnt = jnp.sum(ok, axis=-1).astype(jnp.int32)
    return _window_to_global(widx, lidx), ok, cnt, nd2


def blockwise_knn(part: FractalPartition, samp: BWSamples, *, k: int,
                  w: int, chunk: int | None = None,
                  impl: str | None = None) -> BWNeighbors:
    """Block-wise kNN of sampled centers inside their parent window."""
    impl = _resolve(impl)
    out = _chunked_slices(
        _neighbor_slices(part, samp),
        lambda s: _knn_slice(part, s, k=k, w=w, impl=impl), chunk)
    g, ok, cnt, nd2 = out
    return _compact_neighbors(samp, g, ok, cnt, nd2, k)


def _compact_neighbors(samp: BWSamples, gidx, mask, cnt, d2, num):
    k_out = samp.k_out
    j = jnp.arange(samp.block_mask.shape[1], dtype=jnp.int32)[None, :]
    pos = jnp.where(samp.block_mask, samp.cum_quota[:-1, None] + j, k_out)
    flat = pos.reshape(-1)
    out_i = jnp.zeros((k_out, num), jnp.int32).at[flat].set(
        gidx.reshape(-1, num), mode="drop")
    out_m = jnp.zeros((k_out, num), bool).at[flat].set(
        mask.reshape(-1, num), mode="drop")
    out_c = jnp.zeros((k_out,), jnp.int32).at[flat].set(
        cnt.reshape(-1), mode="drop")
    out_d = jnp.full((k_out, num), _INF).at[flat].set(
        d2.reshape(-1, num), mode="drop")
    return BWNeighbors(idx=out_i, mask=out_m, cnt=out_c, d2=out_d)


def coarse_window_ranges(part: FractalPartition, samp: BWSamples):
    """Per-leaf range [ca, cb) of *coarse samples* in the parent subtree.

    Sampled points inherit the DFT order (compaction is leaf-major), so the
    samples of any subtree form a contiguous range of the compacted sample
    array — the paper's contiguity argument, one level up.
    """
    L = part.leaf_of_slot.shape[0]
    total_depth = max(L.bit_length() - 1, 0)
    slo, shi = subtree_slot_range(part, part.leaf_depth, part.slot_of_leaf,
                                  total_depth)
    slo = jnp.clip(slo, 0, L)
    shi = jnp.clip(shi, 0, L)
    la = part.slot_cum_leaves[slo]
    lb = part.slot_cum_leaves[shi]
    ca = samp.cum_quota[la]
    cb = samp.cum_quota[lb]
    return ca, cb


def _interp_slice(part, samp, feats, sl, *, wc, bs, eps, impl):
    """One leaf-slice of block-wise interpolation; returns scatter payload.

    Plan: coarse candidate windows (contiguous ranges of the compacted
    sample array) + IDW weights.  Execute: the 3-NN select runs through the
    kNN kernel and the feature fetch through the in-window gather kernel.
    """
    n = part.n
    lo, cb, il, ls, lv = sl
    j = jnp.arange(wc, dtype=jnp.int32)
    cidx = lo[:, None] + j[None, :]                       # (c, wc)
    cmask = (cidx < cb[:, None]) & il[:, None]
    cidx = jnp.clip(cidx, 0, samp.k_out - 1)
    cmask = cmask & samp.valid[cidx]
    cpts = lc(samp.coords[cidx], "blocks", None, None)    # (c, wc, 3)

    fine, fmask, fidx = leaf_from(ls, lv, il, part.coords, bs)
    fine = lc(fine, "blocks", None, None)
    nidx, nd2 = kops.knn_blocks(fine, cpts, cmask, k=3, impl=impl)
    nd2 = jnp.maximum(nd2, 0.0)
    ok = nd2 < _INF
    wgt = jnp.where(ok, 1.0 / (nd2 + eps), 0.0)
    wsum = jnp.sum(wgt, axis=-1, keepdims=True)
    wgt = jnp.where(wsum > 0, wgt / jnp.maximum(wsum, eps), 0.0)
    samp_idx = jnp.take_along_axis(
        jnp.broadcast_to(cidx[:, None, :], nidx.shape[:2] + cidx.shape[1:]),
        nidx, axis=-1)                                    # into compacted samp
    c = cidx.shape[0]
    vals = kops.gather_blocks(feats[cidx], nidx.reshape(c, -1), impl=impl)
    vals = vals.reshape(c, bs, 3, feats.shape[-1])        # (c, bs, 3, C)
    blended = jnp.sum(vals * wgt[..., None], axis=-2)     # (c, bs, C)
    flat_pos = jnp.where(fmask, fidx, n).reshape(-1)
    return flat_pos, blended, samp_idx, wgt


def blockwise_interpolate(part: FractalPartition, samp: BWSamples,
                          feats: Array, *, wc: int, bs: int,
                          eps: float = 1e-8, chunk: int | None = None,
                          impl: str | None = None):
    """Block-wise interpolation (paper BWI): 3-NN IDW feature propagation
    from the sampled (coarse) cloud back to every point, with the candidate
    set restricted to coarse samples of the leaf's parent subtree.

    ``feats`` are features of the compacted samples (k_out, C).
    Returns (out (n, C) in sorted order, idx3 (n,3), w3 (n,3)).
    ``chunk`` scans over leaf chunks, scattering into the output carry (the
    live footprint is one chunk's distance/feature tiles).
    """
    impl = _resolve(impl)
    n, ml = part.n, part.ml
    c_feats = feats.shape[-1]
    ca, cb = coarse_window_ranges(part, samp)
    own = samp.cum_quota[jnp.arange(ml)]
    lo = jnp.clip(own - jnp.maximum(0, (wc - samp.quota) // 2),
                  ca, jnp.maximum(ca, cb - wc))
    sl = (lo, cb, part.is_leaf, part.leaf_start, part.leaf_vsize)

    out = jnp.zeros((n, c_feats), feats.dtype)
    idx3 = jnp.zeros((n, 3), jnp.int32)
    w3 = jnp.zeros((n, 3), jnp.float32)

    def scatter(carry, payload):
        out, idx3, w3 = carry
        flat_pos, blended, samp_idx, wgt = payload
        out = lc(out.at[flat_pos].set(
            blended.reshape(-1, c_feats), mode="drop"), "points", None)
        idx3 = idx3.at[flat_pos].set(samp_idx.reshape(-1, 3), mode="drop")
        w3 = w3.at[flat_pos].set(
            wgt.astype(jnp.float32).reshape(-1, 3), mode="drop")
        return out, idx3, w3

    if chunk is None:
        payload = _interp_slice(part, samp, feats, sl, wc=wc, bs=bs,
                                eps=eps, impl=impl)
        out, idx3, w3 = scatter((out, idx3, w3), payload)
    else:
        chunks, _ = kops.leaf_chunks(sl, chunk)

        def body(carry, s):
            payload = _interp_slice(part, samp, feats, s, wc=wc, bs=bs,
                                    eps=eps, impl=impl)
            return scatter(carry, payload), None

        (out, idx3, w3), _ = jax.lax.scan(body, (out, idx3, w3), chunks)
    return out, idx3, w3


def gather(feats: Array, idx: Array) -> Array:
    """Block-wise gathering (paper BWGa). Functionally a take over the
    *compacted* index frame; the in-window Pallas gather kernel
    (``kernels.ops.gather_blocks``) is dispatched where the window structure
    still exists — inside ``blockwise_interpolate`` — because each of its
    ``idx`` rows only touches one VMEM-resident parent window."""
    return feats[idx]

"""Global point operations — the paper's O(n^2) baseline (PointAcc-style).

These are the *oracles*: block-parallel ops in bppo.py are validated against
them (exactness where the search spaces coincide; recall/coverage metrics
where the paper accepts bounded deviation).  They are also the "Original"
bars in the paper's Figs. 3/13/15.

Conventions
-----------
* All ops take a ``valid`` mask so padded clouds compose.
* Ball query returns the ``num`` *nearest* in-radius neighbors (deterministic
  under permutation; the CUDA original returns the first-found ``num``).
  Empty slots are padded with the nearest neighbor index.
* FPS starts from the first valid point (the paper uses a random start; pass
  ``start`` for seeded variants).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

Array = jax.Array
_INF = jnp.float32(3.0e38)


def pairwise_sqdist(a: Array, b: Array) -> Array:
    """(m,3),(n,3) -> (m,n) squared euclidean distances."""
    diff = a[:, None, :] - b[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def fps(coords: Array, valid: Array, k: int, start: Array | int = None):
    """Farthest point sampling. Returns (idx (k,), sel_valid (k,)).

    Iteratively picks the point farthest from the selected set — k linear
    passes over n points = the paper's O(n*k) global search.
    """
    n = coords.shape[0]
    coords = coords.astype(jnp.float32)
    if start is None:
        start = jnp.argmax(valid).astype(jnp.int32)
    else:
        start = jnp.asarray(start, jnp.int32)
    nvalid = jnp.sum(valid)

    def dist_to(i):
        d = coords - coords[i][None, :]
        return jnp.sum(d * d, axis=-1)

    mind0 = jnp.where(valid, dist_to(start), -_INF).at[start].set(-_INF)

    def step(mind, _):
        nxt = jnp.argmax(mind).astype(jnp.int32)
        mind = jnp.minimum(mind, jnp.where(valid, dist_to(nxt), -_INF))
        mind = mind.at[nxt].set(-_INF)
        return mind, nxt

    _, rest = jax.lax.scan(step, mind0, None, length=k - 1)
    idx = jnp.concatenate([start[None], rest])
    sel_valid = jnp.arange(k) < nvalid
    return idx, sel_valid


def _bq_one(center, cvalid, src, src_valid, r2, num):
    d = jnp.sum((src - center[None, :]) ** 2, axis=-1)
    d = jnp.where(src_valid, d, _INF)
    neg, idx = jax.lax.top_k(-d, num)
    d_k = -neg
    in_r = d_k <= r2
    cnt = jnp.sum((d <= r2).astype(jnp.int32))
    idx = jnp.where(in_r, idx, idx[0])  # pad with nearest
    cnt = jnp.where(cvalid, cnt, 0)
    return idx.astype(jnp.int32), cnt


def ball_query(src: Array, src_valid: Array, centers: Array,
               centers_valid: Array, radius: float, num: int,
               chunk: int = 256):
    """(m, num) neighbor indices of up-to-num nearest in-radius points."""
    r2 = jnp.float32(radius) ** 2
    m = centers.shape[0]
    pad = (-m) % chunk
    c = jnp.pad(centers.astype(jnp.float32), ((0, pad), (0, 0)))
    cv = jnp.pad(centers_valid, (0, pad))

    def body(carry, xs):
        cc, ccv = xs
        idx, cnt = jax.vmap(
            lambda p, v: _bq_one(p, v, src.astype(jnp.float32), src_valid,
                                 r2, num))(cc, ccv)
        return carry, (idx, cnt)

    _, (idx, cnt) = jax.lax.scan(
        body, None, (c.reshape(-1, chunk, 3), cv.reshape(-1, chunk)))
    return idx.reshape(-1, num)[:m], cnt.reshape(-1)[:m]


def knn(src: Array, src_valid: Array, queries: Array, k: int,
        chunk: int = 256):
    """k nearest neighbors: returns (idx (m,k), sqdist (m,k))."""
    m = queries.shape[0]
    pad = (-m) % chunk
    q = jnp.pad(queries.astype(jnp.float32), ((0, pad), (0, 0)))
    srcf = src.astype(jnp.float32)

    def body(carry, qq):
        d = pairwise_sqdist(qq, srcf)
        d = jnp.where(src_valid[None, :], d, _INF)
        neg, idx = jax.lax.top_k(-d, k)
        return carry, (idx.astype(jnp.int32), -neg)

    _, (idx, d2) = jax.lax.scan(body, None, q.reshape(-1, chunk, 3))
    return idx.reshape(-1, k)[:m], d2.reshape(-1, k)[:m]


def gather(feats: Array, idx: Array) -> Array:
    """Feature gathering: feats (n, c), idx (...,) -> (..., c)."""
    return feats[idx]


def interpolate_3nn(queries: Array, src: Array, src_valid: Array,
                    feats: Array, eps: float = 1e-8):
    """Inverse-distance-weighted 3-NN feature propagation (paper Fig. 2c)."""
    idx, d2 = knn(src, src_valid, queries, k=3)
    w = 1.0 / (d2 + eps)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return jnp.sum(feats[idx] * w[..., None], axis=-2), idx, w

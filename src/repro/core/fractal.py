"""Fractal: shape-aware, sorter-free point-cloud partitioning (paper Alg. 1).

The partition engine is *level-synchronous*: level ``l`` holds ``2**l`` tree
nodes; points are kept contiguous-by-node in depth-first (DFT) order, which
is the paper's memory layout (Fig. 6).  One level costs a constant number of
linear passes (segment min/max + 3 cumsums + 1 scatter) — the TPU analogue of
the paper's "inclusive traverser" (comparators + counters, no sorter).

Strategies share the engine and differ only in how the split value ``mid`` is
produced:

* ``fractal``  — mid = (max+min)/2 of the *points* in the node (paper).
* ``uniform``  — mid = center of the node's spatial cell (PNNPU-style);
  non-adaptive (splits to full depth regardless of occupancy).
* ``octree``   — uniform cell-center split but adaptive (stops at ``th``);
  three consecutive binary levels == one octree level.
* ``kdtree``   — mid = median (Crescent-style); implemented with a real
  per-level sort so the sorter-vs-traverser cost gap is measurable.

Invariants maintained (tested in tests/test_fractal.py):
  * ``perm`` is a permutation of [0, n);
  * every node's range is [valid points | invalid points] (invalid only ever
    accumulate at the *end* of a range, along the rightmost spine);
  * every subtree is a contiguous range (DFT property);
  * every real leaf has ``vsize <= th`` unless ``overflowed`` is set.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import warnings
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

FRACTAL = "fractal"
UNIFORM = "uniform"
OCTREE = "octree"
KDTREE = "kdtree"
STRATEGIES = (FRACTAL, UNIFORM, OCTREE, KDTREE)
ON_OVERFLOW = ("warn", "silent")

_BIG = jnp.float32(3.0e38)


class FractalOverflowWarning(UserWarning):
    """A partition hit its depth cap with a leaf still holding >th points."""


class FractalOverflowError(RuntimeError):
    """Raised by ``check_overflow`` on a partition that kept >th leaves."""


def _overflow_warn(overflowed, max_vsize, *, n, th, depth):
    # Host callback: under vmap the flags arrive batched, so reduce.
    if np.any(np.asarray(overflowed)):
        warnings.warn(
            f"fractal partition overflow: a leaf kept "
            f"{int(np.max(np.asarray(max_vsize)))} > th={th} valid points at "
            f"the depth cap (n={n}, depth={depth}); downstream block ops "
            f"will truncate that leaf — raise depth/th or pre-tile the "
            f"cloud (repro.scene)", FractalOverflowWarning, stacklevel=2)


def check_overflow(part: "FractalPartition", th: int | None = None) -> None:
    """Eagerly raise ``FractalOverflowError`` if ``part`` overflowed.

    The jit-compatible path is ``partition(..., on_overflow="warn")`` (a
    host callback); this is the strict host-side twin for callers that
    would rather fail than serve a truncated partition.
    """
    if bool(jnp.any(part.overflowed)):
        mx = int(jnp.max(part.max_leaf_vsize))
        n = part.perm.shape[-1]  # last axis: point count even when batched
        raise FractalOverflowError(
            f"fractal partition overflow: a leaf kept {mx} valid points"
            + (f" > th={th}" if th is not None else "")
            + f" at the depth cap (n={n}); raise depth/th or pre-tile "
            f"the cloud (repro.scene)")


def default_depth(n: int, th: int, slack: int = 9, hard_cap: int = 18) -> int:
    """Static tree depth: ceil(log2(n/th)) plus slack levels.

    The paper's recursion (Alg. 1) is unbounded; with static shapes we give
    clustered data headroom — midpoint splits only *halve the extent* per
    level, so zooming into a dense cluster costs extra levels before the
    point count starts halving.  Adaptive strategies stop early on sparse
    branches, so extra depth costs little.
    """
    if th <= 0:
        raise ValueError(f"th must be positive, got {th}")
    base = max(0, math.ceil(math.log2(max(1, n) / th))) if n > th else 0
    return min(base + (slack if base > 0 else 0), hard_cap)


def max_leaves(n: int, th: int, depth: int) -> int:
    """Static bound on the number of real leaves.

    In a binary tree #leaves = #internal + 1.  Internal (split) nodes all
    hold > th valid points and are disjoint *within a level*, so level l has
    at most min(2**l, n // (th+1)) internal nodes.  (They nest across
    levels, so no global n/(th+1) bound exists — degenerate chains shed one
    point per level.)
    """
    per_level = n // (th + 1)
    total = sum(min(2 ** l, per_level) for l in range(depth))
    return int(min(2 ** depth, total + 1))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FractalPartition:
    """Static-shape partition result (single cloud; vmap for batches)."""

    # Point layout (DFT order).
    perm: Array            # (n,) int32: sorted = x[perm]
    coords: Array          # (n, 3) permuted coordinates
    valid: Array           # (n,) bool, permuted validity
    # Compacted leaves (DFT order), ML = max_leaves slots.
    leaf_start: Array      # (ML,) int32 range start into permuted arrays
    leaf_rsize: Array      # (ML,) int32 range length (incl. trailing invalid)
    leaf_vsize: Array      # (ML,) int32 number of valid points
    leaf_depth: Array      # (ML,) int32 tree depth at which the leaf stopped
    is_leaf: Array         # (ML,) bool slot holds a real leaf
    # Paper's search-space rule: depth>=2 -> immediate parent; else the leaf.
    parent_start: Array    # (ML,) int32
    parent_rsize: Array    # (ML,) int32
    parent_vsize: Array    # (ML,) int32
    # Level-D slot bookkeeping (L = 2**depth slots).
    slot_of_leaf: Array    # (ML,) int32 level-D slot id of each compact leaf
    leaf_of_slot: Array    # (L,) int32 compact index of slot's leaf (or -1)
    slot_cum_leaves: Array # (L+1,) int32 prefix count of real leaves by slot
    # Diagnostics.
    num_leaves: Array      # () int32
    traversals: Array      # () int32 levels in which any node split (paper's
                           # "traversal" count: 11 for 289K @ th=256)
    sort_passes: Array     # () int32 number of O(n log n) sorts (0 = fractal)
    overflowed: Array      # () bool some leaf kept >th valid points
    leaf_capacity_exceeded: Array  # () bool more real leaves than ML slots
    max_leaf_vsize: Array  # () int32

    @property
    def n(self) -> int:
        return self.perm.shape[0]

    @property
    def ml(self) -> int:
        return self.leaf_start.shape[0]


def _segment_minmax(x: Array, valid: Array, seg: Array, num: int):
    big = _BIG.astype(x.dtype)
    lo = jax.ops.segment_min(jnp.where(valid, x, big), seg, num_segments=num,
                             indices_are_sorted=True)
    hi = jax.ops.segment_max(jnp.where(valid, x, -big), seg, num_segments=num,
                             indices_are_sorted=True)
    return lo, hi


def _exclusive_cumsum(x: Array) -> Array:
    return jnp.concatenate([jnp.zeros((1,), x.dtype), jnp.cumsum(x)[:-1]])


def partition(
    coords: Array,
    valid: Array | None = None,
    *,
    th: int,
    depth: int | None = None,
    strategy: str = FRACTAL,
    max_leaves_: int | None = None,
    dim0: int | Array = 0,
    on_overflow: str = "warn",
) -> FractalPartition:
    """Partition a point cloud into <=th-point blocks in DFT memory order.

    ``dim0`` offsets the split-dimension cycle: level ``l`` splits on
    dimension ``(l + dim0) % 3``.  A traced int32 scalar is accepted, so a
    vmapped plan can phase each cloud independently — the scene tiler uses
    this to make a tile's local tree reproduce the global subtree rooted at
    the tile node (a node at depth ``d`` splits on ``d % 3``; see
    docs/DESIGN.md §10).

    ``on_overflow="warn"`` emits a ``FractalOverflowWarning`` (via a host
    callback, jit/vmap-safe) when the depth cap leaves a leaf with more
    than ``th`` valid points, naming the offending (n, th, depth);
    ``"silent"`` restores the old behaviour (timed benchmark loops opt in
    so the callback never sits inside a measured executable).  Strict
    callers raise instead with ``check_overflow``.
    """
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r}")
    if on_overflow not in ON_OVERFLOW:
        raise ValueError(f"on_overflow must be one of {ON_OVERFLOW}, "
                         f"got {on_overflow!r}")
    n = coords.shape[0]
    if valid is None:
        valid = jnp.ones((n,), bool)
    if depth is None:
        # Uniform grids are non-adaptive: depth is the grid resolution and
        # every level-D cell is a leaf, so no imbalance slack is added.
        depth = (default_depth(n, th, slack=0) if strategy == UNIFORM
                 else default_depth(n, th))
    if max_leaves_ is not None:
        ml = max_leaves_
    elif strategy == UNIFORM:
        ml = 2 ** depth  # non-adaptive: every level-D cell is a leaf
    else:
        ml = max_leaves(n, th, depth)
    adaptive = strategy != UNIFORM
    needs_bbox = strategy in (UNIFORM, OCTREE)

    coords = coords.astype(jnp.float32)
    pts = coords
    vld = valid
    orig = jnp.arange(n, dtype=jnp.int32)
    node = jnp.zeros((n,), jnp.int32)

    # Node state for the current level (size 2**l).
    start = jnp.zeros((1,), jnp.int32)
    rsize = jnp.full((1,), n, jnp.int32)
    vsize = jnp.sum(vld).astype(jnp.int32)[None]
    exists = jnp.ones((1,), bool)
    if needs_bbox:
        glo = jnp.min(jnp.where(vld[:, None], coords, _BIG), axis=0)
        ghi = jnp.max(jnp.where(vld[:, None], coords, -_BIG), axis=0)
        box_lo, box_hi = glo[None], ghi[None]  # (2**l, 3)

    # Per-level leaf records, folded into level-D slots at the end.
    leaf_records = []  # (level, is_leaf(2**l,), start, rsize, vsize,
                       #  pstart, prsize, pvsize)
    traversals = jnp.zeros((), jnp.int32)
    sort_passes = jnp.zeros((), jnp.int32)

    pstart = start  # parent ranges seen by this level's nodes (root: itself)
    prsize = rsize
    pvsize = vsize

    for lvl in range(depth + 1):
        nn = 2 ** lvl
        want_split = vsize > th if adaptive else jnp.ones((nn,), bool)
        active = exists & want_split & (lvl < depth)

        is_leaf_here = exists & ~active
        leaf_records.append(
            (lvl, is_leaf_here, start, rsize, vsize, pstart, prsize, pvsize))
        if lvl == depth:
            break

        # Static python int when dim0 is 0/int (the common case, compiles
        # to a strided slice); a traced scalar otherwise (gather on axis 1).
        dim = (lvl + dim0) % 3
        x = pts[:, dim]
        if strategy == FRACTAL:
            lo, hi = _segment_minmax(x, vld, node, nn)
            mid = (lo + hi) * 0.5
        elif strategy in (UNIFORM, OCTREE):
            mid = (box_lo[:, dim] + box_hi[:, dim]) * 0.5
        else:  # KDTREE: median via an honest per-level sort (the paper's
            # "exclusive sorter" — costed so benchmarks expose the gap).
            skey = jnp.where(vld, x, _BIG)
            order = jnp.lexsort((skey, node))
            sorted_node = node[order]
            pos_in_node = jnp.arange(n, dtype=jnp.int32) - start[sorted_node]
            med_rank = (jnp.maximum(vsize, 1) - 1) // 2
            is_med = pos_in_node == med_rank[sorted_node]
            mid = jax.ops.segment_max(
                jnp.where(is_med, skey[order], -_BIG), sorted_node,
                num_segments=nn, indices_are_sorted=True)
            sort_passes = sort_passes + 1

        traversals = traversals + jnp.any(active).astype(jnp.int32)

        node_active = active[node]
        node_mid = mid[node]
        # Partition key: 0 = left-valid, 1 = right-valid, 2 = invalid (always
        # ordered last within the node; goes right iff the node splits).
        side = (x > node_mid).astype(jnp.int32)
        key = jnp.where(vld, jnp.where(node_active, side, 0), 2)
        child = jnp.where(node_active, (key > 0).astype(jnp.int32), 0)

        # Stable segmented partition via cumsums (no sort). Points are
        # contiguous by node, so within-node running ranks are global
        # exclusive cumsums minus their value at the node start.
        onehot = [(key == k).astype(jnp.int32) for k in range(3)]
        cnt = [jax.ops.segment_sum(o, node, num_segments=nn,
                                   indices_are_sorted=True) for o in onehot]
        excl = [_exclusive_cumsum(o) for o in onehot]
        rank = sum(jnp.where(key == k, excl[k] - excl[k][start[node]], 0)
                   for k in range(3))
        offset = (jnp.where(key >= 1, cnt[0][node], 0)
                  + jnp.where(key >= 2, cnt[1][node], 0))
        newpos = start[node] + offset + rank

        scat = lambda a: jnp.zeros_like(a).at[newpos].set(a)
        pts = scat(pts)
        vld = scat(vld)
        orig = scat(orig)
        new_node = node * 2 + child
        node = scat(new_node)

        # Child node state (2**(l+1)).
        idx2 = jnp.arange(2 * nn, dtype=jnp.int32)
        par = idx2 // 2
        is_right = idx2 % 2
        l_r = cnt[0]
        l_v = cnt[0]
        r_v = jnp.where(active, cnt[1], 0)
        r_r = jnp.where(active, rsize - cnt[0], 0)
        l_rr = jnp.where(active, l_r, rsize)   # inactive: all to child 0
        l_vv = jnp.where(active, l_v, vsize)
        new_rsize = jnp.where(is_right == 0, l_rr[par], r_r[par])
        new_vsize = jnp.where(is_right == 0, l_vv[par], r_v[par])
        new_start = _exclusive_cumsum(new_rsize).astype(jnp.int32)
        new_exists = exists[par] & active[par]

        pstart, prsize, pvsize = start[par], rsize[par], vsize[par]
        if needs_bbox:
            new_lo = box_lo[par]
            new_hi = box_hi[par]
            d_onehot = (jnp.arange(3) == dim)
            new_lo = jnp.where(d_onehot[None, :] & (is_right == 1)[:, None],
                               mid[par][:, None], new_lo)
            new_hi = jnp.where(d_onehot[None, :] & (is_right == 0)[:, None],
                               mid[par][:, None], new_hi)
            box_lo, box_hi = new_lo, new_hi

        start, rsize, vsize, exists = new_start, new_rsize, new_vsize, new_exists

    # ---- Fold per-level leaves into level-D slots, then compact. ----
    L = 2 ** depth
    slot_is_leaf = jnp.zeros((L,), bool)
    slot_start = jnp.zeros((L,), jnp.int32)
    slot_rsize = jnp.zeros((L,), jnp.int32)
    slot_vsize = jnp.zeros((L,), jnp.int32)
    slot_depth = jnp.zeros((L,), jnp.int32)
    slot_pstart = jnp.zeros((L,), jnp.int32)
    slot_prsize = jnp.zeros((L,), jnp.int32)
    slot_pvsize = jnp.zeros((L,), jnp.int32)
    for (lvl, isl, st, rs, vs, ps, prs, pvs) in leaf_records:
        shift = depth - lvl
        slots = (jnp.arange(2 ** lvl, dtype=jnp.int32) << shift)
        # Paper rule: depth-0/1 leaves search themselves; deeper leaves use
        # their immediate parent.
        use_self = lvl <= 1
        p_st = st if use_self else ps
        p_rs = rs if use_self else prs
        p_vs = vs if use_self else pvs
        upd = lambda dst, val: dst.at[slots].set(jnp.where(isl, val, dst[slots]))
        slot_is_leaf = slot_is_leaf.at[slots].set(
            jnp.where(isl, True, slot_is_leaf[slots]))
        slot_start = upd(slot_start, st)
        slot_rsize = upd(slot_rsize, rs)
        slot_vsize = upd(slot_vsize, vs)
        slot_depth = upd(slot_depth, jnp.full_like(st, lvl))
        slot_pstart = upd(slot_pstart, p_st)
        slot_prsize = upd(slot_prsize, p_rs)
        slot_pvsize = upd(slot_pvsize, p_vs)

    cum = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                           jnp.cumsum(slot_is_leaf.astype(jnp.int32))])
    num_leaves = cum[-1]
    compact_idx = cum[:-1]  # slot -> compact position (where is_leaf)
    leaf_of_slot = jnp.where(slot_is_leaf, compact_idx, -1)

    def compact(a, fill=0):
        out = jnp.full((ml,), fill, a.dtype)
        return out.at[jnp.where(slot_is_leaf, compact_idx, ml)].set(
            a, mode="drop")

    is_leaf_c = jnp.arange(ml) < num_leaves
    slot_ids = jnp.arange(L, dtype=jnp.int32)
    part = FractalPartition(
        perm=orig,
        coords=pts,
        valid=vld,
        leaf_start=compact(slot_start),
        leaf_rsize=compact(slot_rsize),
        leaf_vsize=compact(slot_vsize),
        leaf_depth=compact(slot_depth),
        is_leaf=is_leaf_c,
        parent_start=compact(slot_pstart),
        parent_rsize=compact(slot_prsize),
        parent_vsize=compact(slot_pvsize),
        slot_of_leaf=compact(slot_ids, fill=-1),
        leaf_of_slot=leaf_of_slot,
        slot_cum_leaves=cum,
        num_leaves=num_leaves,
        traversals=traversals,
        sort_passes=sort_passes,
        overflowed=jnp.any(slot_is_leaf & (slot_vsize > th)),
        leaf_capacity_exceeded=num_leaves > ml,
        max_leaf_vsize=jnp.max(jnp.where(slot_is_leaf, slot_vsize, 0)),
    )
    if on_overflow == "warn" and adaptive and n > th:
        jax.debug.callback(
            functools.partial(_overflow_warn, n=n, th=th, depth=depth),
            part.overflowed, part.max_leaf_vsize)
    return part


# ---------------------------------------------------------------------------
# Block / window views (padded gathers over the DFT-contiguous layout).
# ---------------------------------------------------------------------------

def leaf_from(leaf_start, leaf_vsize, is_leaf, data, bs: int):
    """Slice-level leaf view (leading dim = any subset of leaves)."""
    n = data.shape[0]
    j = jnp.arange(bs, dtype=jnp.int32)
    idx = leaf_start[:, None] + j[None, :]
    mask = is_leaf[:, None] & (j[None, :] < leaf_vsize[:, None])
    idx = jnp.clip(idx, 0, n - 1)
    return data[idx], mask, idx


def leaf_view(part: FractalPartition, data: Array, bs: int):
    """Gather per-leaf data to a padded (ML, bs, ...) view.

    ``data`` must be in permuted (DFT) order, leading dim n.  Returns
    (view, mask) where mask marks valid points of real leaves.
    """
    return leaf_from(part.leaf_start, part.leaf_vsize, part.is_leaf, data,
                     bs)


def window_from(leaf_start, leaf_vsize, parent_start, parent_vsize,
                is_leaf, data, valid, w: int):
    """Slice-level search-space window (see window_view)."""
    n = data.shape[0]
    want = (leaf_start - jnp.maximum(0, (w - leaf_vsize) // 2))
    lo = jnp.clip(want, parent_start,
                  jnp.maximum(parent_start, parent_start + parent_vsize - w))
    j = jnp.arange(w, dtype=jnp.int32)
    idx = lo[:, None] + j[None, :]
    valid_end = parent_start + parent_vsize
    mask = is_leaf[:, None] & (idx < valid_end[:, None])
    mask = mask & valid[jnp.clip(idx, 0, n - 1)]
    idx = jnp.clip(idx, 0, n - 1)
    return data[idx], mask, idx


def window_view(part: FractalPartition, data: Array, w: int):
    """Per-leaf *search-space* window into the parent range, padded to w.

    The window is centered on the leaf and clamped inside the parent's
    *valid prefix*, so the leaf's valid points are always covered when
    w >= leaf_vsize (bounded truncation of pathological parents — the
    on-chip block budget of the paper).  Placement depends only on valid
    counts: invalid points sink to the end of every range (§3), so a
    bucket-padded cloud places its windows exactly where the unpadded
    cloud does — the §9 padding-invisibility contract.  Windows may still
    cover stray invalid slots, so a mask is returned.
    """
    return window_from(part.leaf_start, part.leaf_vsize, part.parent_start,
                       part.parent_vsize, part.is_leaf,
                       data, part.valid, w)


def subtree_slot_range(part: FractalPartition, depth_arr: Array,
                       slot: Array, total_depth: int):
    """Level-D slot range [lo, hi) of the subtree rooted at a leaf's parent."""
    shift = jnp.maximum(total_depth - jnp.maximum(depth_arr - 1, 0), 0)
    parent_slot = (slot >> shift) << shift
    return parent_slot, parent_slot + (1 << shift)

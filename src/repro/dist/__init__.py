"""repro.dist — the distribution subsystem.

* ``logical``     logical-axis sharding rules + the ``lc`` constraint helper
* ``elastic``     degraded-device mesh selection
* ``compression`` gradient codecs (bf16 / stochastic int8) + error feedback
* ``compat``      jax-version shims for mesh construction

Importing the package installs the jax compat shims (``AxisType`` and the
``axis_types``-tolerant ``jax.make_mesh``) so call sites written against
jax >= 0.5 run on the 0.4.x line too.
"""
from repro.dist import compat

compat.install()

from repro.dist import compression, elastic, logical  # noqa: E402

__all__ = ["compat", "compression", "elastic", "logical"]

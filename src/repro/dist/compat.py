"""jax version compatibility for mesh construction.

The launchers (and the sharding tests) build meshes with
``jax.make_mesh(shape, names, axis_types=(AxisType.Auto, ...))``.  The
``axis_types`` knob only exists in jax >= 0.5 (sharding-in-types); on the
0.4.x line every mesh axis is implicitly "auto" (GSPMD infers shardings),
so ignoring the argument is semantics-preserving.  ``install()`` fills the
two gaps in-place so call sites written against the newer API run on both:

* ``jax.sharding.AxisType`` (Auto/Explicit/Manual enum) if missing;
* a ``jax.make_mesh`` wrapper that accepts-and-drops ``axis_types``.
"""
from __future__ import annotations

import enum
import functools
import inspect

import jax


class _CompatAxisType(enum.Enum):
    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


AxisType = getattr(jax.sharding, "AxisType", _CompatAxisType)

_installed = False


def install():
    """Idempotently patch the jax namespace (no-op on jax >= 0.5)."""
    global _installed
    if _installed:
        return
    _installed = True
    if not hasattr(jax.sharding, "AxisType"):
        jax.sharding.AxisType = _CompatAxisType
    if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        orig = jax.make_mesh

        @functools.wraps(orig)
        def _make_mesh(axis_shapes, axis_names, *, devices=None,
                       axis_types=None):
            del axis_types  # pre-0.5 jax: every axis is Auto
            return orig(axis_shapes, axis_names, devices=devices)

        jax.make_mesh = _make_mesh


def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
    """``jax.make_mesh`` that tolerates ``axis_types`` on any jax version."""
    install()
    return jax.make_mesh(axis_shapes, axis_names, devices=devices,
                         axis_types=axis_types)

"""Gradient compression codecs + error feedback.

Cross-pod gradient reduction is wire-bound, so grads are compressed before
the reduce: ``bf16`` (2x, deterministic) or ``int8`` (4x, per-tensor scale
with *stochastic rounding* so the quantizer is unbiased).  Both codecs are
lossy; ``apply_error_feedback`` keeps the per-tensor quantization residual
and re-injects it into the next step's gradient (EF-SGD), which restores
convergence to the uncompressed optimum.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

METHODS = ("bf16", "int8")


def compress(x, method: str, key=None):
    """x -> (payload, meta).  ``meta`` is the int8 per-tensor scale
    (max |x|), or None for bf16.  ``key`` drives stochastic rounding and is
    required for int8."""
    if method == "bf16":
        return x.astype(jnp.bfloat16), None
    if method == "int8":
        if key is None:
            raise ValueError("int8 compression needs a PRNG key "
                             "(stochastic rounding)")
        x = x.astype(jnp.float32)
        scale = jnp.max(jnp.abs(x))
        y = x * (127.0 / jnp.maximum(scale, jnp.finfo(jnp.float32).tiny))
        lo = jnp.floor(y)
        frac = y - lo
        q = lo + (jax.random.uniform(key, x.shape) < frac)
        payload = jnp.clip(q, -127, 127).astype(jnp.int8)
        return payload, scale
    raise ValueError(f"unknown compression method {method!r}; "
                     f"have {METHODS}")


def decompress(payload, meta, method: str):
    if method == "bf16":
        return payload.astype(jnp.float32)
    if method == "int8":
        return payload.astype(jnp.float32) * (meta / 127.0)
    raise ValueError(f"unknown compression method {method!r}; "
                     f"have {METHODS}")


def roundtrip(x, method: str, key=None):
    """Compress-then-decompress (what the receiving end of the reduce
    sees), dtype-preserving."""
    payload, meta = compress(x, method, key)
    return decompress(payload, meta, method).astype(x.dtype)


def init_residual(params):
    """Zero error-feedback residuals mirroring the parameter tree."""
    return jax.tree.map(
        lambda p: jnp.zeros(jnp.shape(p), jnp.float32), params)


def apply_error_feedback(grads, residual, method: str, key):
    """EF step: compress (grad + residual), carry the quantization error.

    Returns (decompressed grads to feed the optimizer, new residual)."""
    g_leaves, treedef = jax.tree.flatten(grads)
    r_leaves = treedef.flatten_up_to(residual)
    keys = jax.random.split(key, len(g_leaves))
    out, new_res = [], []
    for g, r, k in zip(g_leaves, r_leaves, keys):
        acc = g.astype(jnp.float32) + r
        dec = roundtrip(acc, method, k)
        out.append(dec.astype(jnp.asarray(g).dtype))
        new_res.append(acc - dec)
    return (jax.tree.unflatten(treedef, out),
            jax.tree.unflatten(treedef, new_res))

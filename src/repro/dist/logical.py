"""Logical axis -> mesh axis sharding rules (MaxText-style).

Every tensor dimension in the model code is named with a *logical* axis
("batch", "ff", "blocks", ...) — once, where the tensor is created.  A
rules dict maps logical names to physical mesh axes; ``logical_rules``
activates (mesh, rules) for a region of code, and ``lc`` applies the
resulting sharding constraint to a value.  Swapping the parallelism
strategy (see launch/perf.py variants) is then a rules edit, not a model
edit.

Resolution semantics (flax.linen.partitioning-style):

* a rule value may be a single mesh axis (``"model"``), a tuple of mesh
  axes (``("pod", "data")``), or ``None`` (replicate);
* mesh axes absent from the current mesh are dropped (the same rules file
  serves the 512-chip two-pod mesh and the 8-device host mesh);
* within one spec each mesh axis is used at most once.  Conflicts are
  resolved by *rule priority* — the order of keys in the rules dict — so
  e.g. ``seq_shard`` (sequence-parallel v0 baseline) beats ``heads`` when
  both map to ``model`` and both appear on one tensor.

Outside a ``logical_rules`` context everything is a no-op: ``lc`` returns
its input unchanged, so single-process tests run the exact sharded code.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# v0 baseline (docs/DESIGN.md §6): clouds/batch -> data axes, fractal leaves and
# tensor-parallel dims -> model, params FSDP-sharded over data.  Key order
# is rule priority (earlier wins a contested mesh axis).
RULES_V0 = {
    # activations
    "batch": ("pod", "data"),     # data parallelism (pods x hosts)
    "seq_shard": "model",         # sequence-parallel attention (train/prefill)
    "kv_seq": "model",            # decode KV-cache sequence
    "blocks": "model",            # fractal leaves -> chips (paper §IV-B)
    "expert_cap": "model",        # MoE capacity rows (TP)
    # parameters
    "experts": "data",            # expert parallelism
    "embed_fsdp": "data",         # FSDP shard dim of weight matrices
    "ff": "model",                # MLP hidden / fused head dim (TP)
    "vocab": "model",             # embedding / logits vocab dim
    "heads": "model",             # attention heads (perf variants)
    "ssm_heads": "model",         # mamba / SSD heads
    # replicated-by-default names (kept explicit so rules_with can flip them)
    "embed": None,                # activation d_model dim
    "points": None,               # flat per-point tensors
    "layers": None,               # stacked scan/cache leading dim
}


def rules_with(**overrides):
    """RULES_V0 with per-variant overrides (``ff=None``, ``points="model"``,
    ``batch=("pod", "data", "model")``, ...)."""
    rules = dict(RULES_V0)
    rules.update(overrides)
    return rules


class _Ctx:
    """An active (mesh, rules) binding."""

    __slots__ = ("mesh", "rules", "mesh_sizes")

    def __init__(self, mesh, rules):
        self.mesh = mesh
        self.rules = dict(rules)
        self.mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))


_LOCAL = threading.local()


def _stack():
    if not hasattr(_LOCAL, "stack"):
        _LOCAL.stack = []
    return _LOCAL.stack


def current() -> _Ctx | None:
    stack = _stack()
    return stack[-1] if stack else None


@contextlib.contextmanager
def logical_rules(mesh, rules):
    """Activate (mesh, rules) for ``lc`` / ``spec`` / ``axis_size``.

    Jitted functions must be *traced* inside the context (call them inside
    the ``with`` block); the constraints are baked into the jaxpr."""
    stack = _stack()
    stack.append(_Ctx(mesh, rules))
    try:
        yield stack[-1]
    finally:
        stack.pop()


def _axis_to_mesh(ctx: _Ctx, axis, used=None):
    """One logical axis -> mesh-axes spec entry (str | tuple | None).

    Preserves the rule's str/tuple form; drops mesh axes absent from the
    mesh or already consumed (``used`` set) in the enclosing spec."""
    if axis is None:
        return None
    rule = ctx.rules.get(axis)
    if rule is None:
        return None
    if isinstance(rule, str):
        if rule in ctx.mesh_sizes and (used is None or rule not in used):
            if used is not None:
                used.add(rule)
            return rule
        return None
    kept = tuple(a for a in rule
                 if a in ctx.mesh_sizes and (used is None or a not in used))
    if not kept:
        return None
    if used is not None:
        used.update(kept)
    return kept


def _spec_entries(ctx: _Ctx, axes):
    """All dims of one tensor -> spec entries, with priority resolution.

    Dims are assigned in rule-priority order (position of the logical name
    in the rules dict), so when two dims contend for one mesh axis the
    higher-priority logical axis wins and the other replicates."""
    prio = {name: i for i, name in enumerate(ctx.rules)}
    order = sorted(range(len(axes)),
                   key=lambda d: prio.get(axes[d], len(prio)))
    used: set = set()
    entries = [None] * len(axes)
    for d in order:
        entries[d] = _axis_to_mesh(ctx, axes[d], used)
    return entries


def spec(axes) -> P:
    """Logical axes tuple -> PartitionSpec under the active context
    (``P()`` when no context is active)."""
    ctx = current()
    if ctx is None:
        return P()
    return P(*_spec_entries(ctx, tuple(axes)))


def axis_size(name: str) -> int:
    """Product of the mesh-axis sizes a logical axis maps to (1 outside a
    context, or when the axis replicates)."""
    ctx = current()
    if ctx is None:
        return 1
    entry = _axis_to_mesh(ctx, name)
    if entry is None:
        return 1
    axes = (entry,) if isinstance(entry, str) else entry
    size = 1
    for a in axes:
        size *= ctx.mesh_sizes[a]
    return size


def lc(x, *axes):
    """Logical sharding constraint: ``lc(x, "batch", None, "ff")``.

    No-op (returns ``x``) outside a ``logical_rules`` context; inside one,
    applies ``with_sharding_constraint`` with the resolved NamedSharding."""
    ctx = current()
    if ctx is None:
        return x
    if len(axes) != x.ndim:
        raise ValueError(f"lc: {len(axes)} axis names for rank-{x.ndim} "
                         f"value {getattr(x, 'shape', ())}: {axes}")
    entries = _spec_entries(ctx, axes)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*entries)))


def _entry_size(mesh_sizes, entry) -> int:
    if entry is None:
        return 1
    axes = (entry,) if isinstance(entry, str) else entry
    size = 1
    for a in axes:
        size *= mesh_sizes[a]
    return size


def entry_size(mesh, entry) -> int:
    """Device count along one PartitionSpec entry (str | tuple | None)."""
    return _entry_size(dict(zip(mesh.axis_names, mesh.devices.shape)),
                       entry)


def fit_specs(shard_tree, shape_tree, mesh):
    """Null out spec entries whose device count does not divide the dim.

    ``device_put`` and jit argument shardings must divide evenly; reduced
    configs (odd widths) and small batches (batch=1 decode) routinely
    don't, so launchers fit the derived specs against the actual shapes."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(sh, val):
        new = []
        for dim, entry in enumerate(sh.spec):
            if entry is not None and val.shape[dim] % _entry_size(sizes,
                                                                  entry):
                entry = None
            new.append(entry)
        return NamedSharding(mesh, P(*new))

    return jax.tree.map(one, shard_tree, shape_tree)


def _is_axes_leaf(node) -> bool:
    """Leaves of a logical-axes tree: None, or a tuple of axis names."""
    return node is None or (
        isinstance(node, tuple)
        and all(e is None or isinstance(e, str) for e in node))


def param_specs(axes_tree, mesh, rules=None):
    """Logical-axes tree -> NamedSharding tree for ``jax.device_put`` /
    ``jit`` in_shardings.  ``None`` leaves replicate (``P()``); mesh axes
    absent from ``mesh`` are dropped."""
    ctx = _Ctx(mesh, RULES_V0 if rules is None else rules)

    def one(axes):
        if axes is None:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(*_spec_entries(ctx, axes)))

    return jax.tree.map(one, axes_tree, is_leaf=_is_axes_leaf)

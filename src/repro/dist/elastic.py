"""Elastic mesh selection for degraded-device serving.

A production pod loses hosts (8 chips each) without warning; serving must
keep running on whatever is left.  ``choose_mesh_shape`` picks the best
(data, model) factorization for an arbitrary device count — full healthy
pods get the canonical production shapes (launch/mesh.py), odd counts get
the largest model axis (<= the requested one) that still divides evenly.
``degraded_meshes`` enumerates the host-loss sequence so launchers can
pre-compile the fallback meshes before they are needed.
"""
from __future__ import annotations

import jax

from repro.dist import compat

HOST_SIZE = 8     # chips per host — the failure granularity
POD_SIZE = 256    # chips per pod (v5e-256)


def choose_mesh_shape(n_devices: int, *, model_axis: int = 16,
                      pod_size: int = POD_SIZE):
    """Device count -> (mesh shape, axis names).

    Multi-pod counts shard over ("pod", "data", "model") with the fixed
    production per-pod topology (16 x pod_size/16 — ``model_axis`` does
    not apply there); anything else gets ("data", "model") with the
    largest model axis <= ``model_axis`` that divides ``n_devices`` (a
    lost host rarely leaves a power of two).
    """
    if n_devices <= 0:
        raise ValueError(f"need at least one device, got {n_devices}")
    if (n_devices >= 2 * pod_size and n_devices % pod_size == 0
            and pod_size >= 16 and pod_size % 16 == 0):
        return ((n_devices // pod_size, 16, pod_size // 16),
                ("pod", "data", "model"))
    m = min(model_axis, n_devices)
    while n_devices % m:
        m -= 1
    return (n_devices // m, m), ("data", "model")


def degraded_meshes(n_devices: int, n_losses: int, *,
                    host_size: int = HOST_SIZE, model_axis: int = 16):
    """The host-loss degradation sequence: [(shape, names)] for the healthy
    mesh and each of ``n_losses`` successive lost hosts."""
    return [choose_mesh_shape(n_devices - i * host_size,
                              model_axis=model_axis)
            for i in range(n_losses + 1)]


def make_mesh(*, model_axis: int = 2, devices=None):
    """Build a Mesh over the devices that actually exist right now (the
    elastic analogue of launch/mesh.make_production_mesh)."""
    devices = jax.devices() if devices is None else list(devices)
    shape, names = choose_mesh_shape(len(devices), model_axis=model_axis)
    return compat.make_mesh(shape, names, devices=devices,
                            axis_types=(compat.AxisType.Auto,) * len(names))

"""gemma3-12b [dense]: 48L d3840 16H (GQA kv=8) d_ff=15360 vocab=262144,
5:1 local:global (window 1024), QK-norm, 128k context.
[hf:google/gemma-3-*; unverified]

Deviation: one rope_theta is used for both local and global layers (the
reference uses 10k local / 1M global)."""
from repro.lm.model import LMConfig

ARCH_ID = "gemma3-12b"


def config(**kw) -> LMConfig:
    base = dict(
        name=ARCH_ID,
        n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8,
        head_dim=256, d_ff=15_360, vocab=262_144,
        pattern=("local",) * 5 + ("attn",), window=1024,
        qk_norm=True, emb_scale=True, mlp_kind="geglu",
        rope_theta=1_000_000.0, tie_embeddings=True,
        long_context_ok=False,
    )
    base.update(kw)
    return LMConfig(**base)


def reduced(**kw) -> LMConfig:
    base = dict(
        name=ARCH_ID + "-reduced",
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, pattern=("local",) * 5 + ("attn",), window=16,
        qk_norm=True, emb_scale=True, mlp_kind="geglu",
        tie_embeddings=True, dtype="float32", loss_chunk=64,
    )
    base.update(kw)
    return LMConfig(**base)

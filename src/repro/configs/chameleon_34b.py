"""chameleon-34b [vlm]: 48L d8192 64H (GQA kv=8) d_ff=22016 vocab=65536,
early-fusion VQ image tokens.  [arXiv:2405.09818; unverified]

Early fusion means image patches arrive as VQ token ids *inside the
vocabulary*, so the backbone consumes plain token ids; the VQ tokenizer
frontend is a stub (input_specs() provides token ids).  QK-norm per the
Chameleon recipe."""
from repro.lm.model import LMConfig

ARCH_ID = "chameleon-34b"


def config(**kw) -> LMConfig:
    base = dict(
        name=ARCH_ID,
        n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
        head_dim=128, d_ff=22_016, vocab=65_536,
        pattern=("attn",), qk_norm=True, mlp_kind="swiglu",
        rope_theta=10_000.0, tie_embeddings=False,
        long_context_ok=False,
    )
    base.update(kw)
    return LMConfig(**base)


def reduced(**kw) -> LMConfig:
    base = dict(
        name=ARCH_ID + "-reduced",
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
        d_ff=128, vocab=512, pattern=("attn",), qk_norm=True,
        mlp_kind="swiglu", tie_embeddings=False, dtype="float32",
        loss_chunk=64,
    )
    base.update(kw)
    return LMConfig(**base)

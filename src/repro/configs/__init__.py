"""Config registry: 10 assigned LM architectures + the paper's PNN configs.

``get(arch_id)`` returns the module (with ``config()`` / ``reduced()``);
``lm_config(arch_id)`` / ``lm_reduced(arch_id)`` return LMConfig instances.
"""
from __future__ import annotations

import importlib

from repro.configs.shapes import SHAPES, ShapeSpec, applicable

ARCHS = {
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "llama4-scout-17b-16e": "llama4_scout_17b_16e",
    "zamba2-7b": "zamba2_7b",
    "minitron-4b": "minitron_4b",
    "smollm-135m": "smollm_135m",
    "gemma2-2b": "gemma2_2b",
    "gemma3-12b": "gemma3_12b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "chameleon-34b": "chameleon_34b",
    "xlstm-1.3b": "xlstm_1_3b",
}

PNN_ARCHS = ("pointnet2", "pointnext", "pointvector")


def get(arch_id: str):
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(ARCHS)}")
    return importlib.import_module(f"repro.configs.{ARCHS[arch_id]}")


def lm_config(arch_id: str, **kw):
    return get(arch_id).config(**kw)


def lm_reduced(arch_id: str, **kw):
    return get(arch_id).reduced(**kw)


__all__ = ["ARCHS", "PNN_ARCHS", "SHAPES", "ShapeSpec", "applicable",
           "get", "lm_config", "lm_reduced"]

"""smollm-135m [dense]: 30L d576 9H (GQA kv=3) d_ff=1536 vocab=49152,
llama-arch small.  [hf:HuggingFaceTB/SmolLM-135M]"""
from repro.lm.model import LMConfig

ARCH_ID = "smollm-135m"


def config(**kw) -> LMConfig:
    base = dict(
        name=ARCH_ID,
        n_layers=30, d_model=576, n_heads=9, n_kv_heads=3,
        head_dim=64, d_ff=1536, vocab=49_152,
        pattern=("attn",), mlp_kind="swiglu",
        rope_theta=10_000.0, tie_embeddings=True,
        long_context_ok=False,
    )
    base.update(kw)
    return LMConfig(**base)


def reduced(**kw) -> LMConfig:
    base = dict(
        name=ARCH_ID + "-reduced",
        n_layers=2, d_model=48, n_heads=3, n_kv_heads=1, head_dim=16,
        d_ff=96, vocab=512, pattern=("attn",), mlp_kind="swiglu",
        tie_embeddings=True, dtype="float32", loss_chunk=64,
    )
    base.update(kw)
    return LMConfig(**base)

"""granite-moe-3b-a800m [moe]: 32L d1536 24H (GQA kv=8) moe_ff=512
vocab=49155, 40 experts top-8.  [hf:ibm-granite/granite-3.0-*-base]"""
from repro.lm.model import LMConfig, MoEOpts

ARCH_ID = "granite-moe-3b-a800m"


def config(**kw) -> LMConfig:
    base = dict(
        name=ARCH_ID,
        n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
        head_dim=64, d_ff=512, vocab=49_155,
        pattern=("moe",),
        moe=MoEOpts(num_experts=40, top_k=8, d_ff_expert=512,
                    router_act="softmax", capacity_factor=1.25),
        mlp_kind="swiglu", rope_theta=10_000.0, tie_embeddings=True,
        long_context_ok=False,
    )
    base.update(kw)
    return LMConfig(**base)


def reduced(**kw) -> LMConfig:
    base = dict(
        name=ARCH_ID + "-reduced",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab=512, pattern=("moe",),
        moe=MoEOpts(num_experts=8, top_k=2, d_ff_expert=64,
                    router_act="softmax", capacity_factor=8.0),
        mlp_kind="swiglu", tie_embeddings=True, dtype="float32",
        loss_chunk=64,
    )
    base.update(kw)
    return LMConfig(**base)

"""Assigned input-shape set for the LM-family architectures (40 cells)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg, shape: ShapeSpec) -> tuple[bool, str]:
    """Skip rule: long_500k needs a sub-quadratic family (docs/DESIGN.md §5)."""
    if shape.name == "long_500k" and not cfg.long_context_ok:
        return False, ("skipped: pure full-attention arch; long_500k "
                       "requires sub-quadratic attention (SSM/hybrid)")
    return True, ""

"""minitron-4b [dense]: 32L d3072 24H (GQA kv=8) d_ff=9216 vocab=256000,
pruned nemotron (squared-ReLU MLP).  [arXiv:2407.14679; hf]"""
from repro.lm.model import LMConfig

ARCH_ID = "minitron-4b"


def config(**kw) -> LMConfig:
    base = dict(
        name=ARCH_ID,
        n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
        head_dim=128, d_ff=9216, vocab=256_000,
        pattern=("attn",), mlp_kind="relu2",
        rope_theta=10_000.0, tie_embeddings=False,
        long_context_ok=False,
    )
    base.update(kw)
    return LMConfig(**base)


def reduced(**kw) -> LMConfig:
    base = dict(
        name=ARCH_ID + "-reduced",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, pattern=("attn",), mlp_kind="relu2",
        tie_embeddings=False, dtype="float32", loss_chunk=64,
    )
    base.update(kw)
    return LMConfig(**base)

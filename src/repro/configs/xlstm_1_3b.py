"""xlstm-1.3b [ssm]: 48L d2048 4H vocab=50304, sLSTM + mLSTM blocks (7:1),
d_ff=0 (blocks carry their own projections).  [arXiv:2405.04517; unverified]
"""
from repro.lm.model import LMConfig
from repro.lm.xlstm import XLSTMConfig

ARCH_ID = "xlstm-1.3b"


def config(**kw) -> LMConfig:
    base = dict(
        name=ARCH_ID,
        n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
        head_dim=512, d_ff=0, vocab=50_304,
        pattern=("mlstm",) * 7 + ("slstm",),
        xlstm=XLSTMConfig(n_heads=4, proj_factor=2.0, chunk=64),
        tie_embeddings=True, long_context_ok=True,
    )
    base.update(kw)
    return LMConfig(**base)


def reduced(**kw) -> LMConfig:
    base = dict(
        name=ARCH_ID + "-reduced",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=0, vocab=512, pattern=("mlstm", "mlstm", "mlstm", "slstm"),
        xlstm=XLSTMConfig(n_heads=4, proj_factor=2.0, chunk=8),
        tie_embeddings=True, dtype="float32", loss_chunk=64,
        long_context_ok=True,
    )
    base.update(kw)
    return LMConfig(**base)

"""gemma2-2b [dense]: 26L d2304 8H (GQA kv=4) d_ff=9216 vocab=256000,
local+global alternating (window 4096), attn+final logit softcap,
sandwich norms, GeGLU.  [arXiv:2408.00118; hf]"""
from repro.lm.model import LMConfig

ARCH_ID = "gemma2-2b"


def config(**kw) -> LMConfig:
    base = dict(
        name=ARCH_ID,
        n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4,
        head_dim=256, d_ff=9216, vocab=256_000,
        pattern=("local", "attn"), window=4096,
        attn_softcap=50.0, final_softcap=30.0,
        attn_scale=256 ** -0.5,          # query_pre_attn_scalar
        post_norm=True, emb_scale=True, mlp_kind="geglu",
        rope_theta=10_000.0, tie_embeddings=True,
        long_context_ok=False,
    )
    base.update(kw)
    return LMConfig(**base)


def reduced(**kw) -> LMConfig:
    base = dict(
        name=ARCH_ID + "-reduced",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, pattern=("local", "attn"), window=16,
        attn_softcap=50.0, final_softcap=30.0, attn_scale=16 ** -0.5,
        post_norm=True, emb_scale=True, mlp_kind="geglu",
        tie_embeddings=True, dtype="float32", loss_chunk=64,
    )
    base.update(kw)
    return LMConfig(**base)

"""seamless-m4t-medium [audio]: enc-dec 12L+12L d1024 16H (MHA kv=16)
d_ff=4096 vocab=256206.  [arXiv:2308.11596; hf]

The audio frontend is a STUB per the assignment: ``input_specs()`` feeds
precomputed frame embeddings (B, S, 1024) to the encoder; the decoder is a
standard causal transformer with cross-attention."""
from repro.lm.model import LMConfig

ARCH_ID = "seamless-m4t-medium"


def config(**kw) -> LMConfig:
    base = dict(
        name=ARCH_ID,
        n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
        head_dim=64, d_ff=4096, vocab=256_206,
        pattern=("xattn",), encoder_layers=12,
        mlp_kind="swiglu", rope_theta=10_000.0, tie_embeddings=True,
        long_context_ok=False,
    )
    base.update(kw)
    return LMConfig(**base)


def reduced(**kw) -> LMConfig:
    base = dict(
        name=ARCH_ID + "-reduced",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=512, pattern=("xattn",), encoder_layers=2,
        mlp_kind="swiglu", tie_embeddings=True, dtype="float32",
        loss_chunk=64,
    )
    base.update(kw)
    return LMConfig(**base)

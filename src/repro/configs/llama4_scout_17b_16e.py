"""llama4-scout-17b-16e [moe]: 48L d5120 40H (GQA kv=8) d_ff=8192
vocab=202048, 16 experts top-1 + shared expert each layer (early fusion).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.lm.model import LMConfig, MoEOpts

ARCH_ID = "llama4-scout-17b-16e"


def config(**kw) -> LMConfig:
    base = dict(
        name=ARCH_ID,
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        head_dim=128, d_ff=8192, vocab=202_048,
        pattern=("moe",),
        moe=MoEOpts(num_experts=16, top_k=1, d_ff_expert=8192,
                    shared_ff=8192, router_act="sigmoid",
                    capacity_factor=1.25),
        mlp_kind="swiglu", rope_theta=500_000.0, tie_embeddings=False,
        long_context_ok=False,
    )
    base.update(kw)
    return LMConfig(**base)


def reduced(**kw) -> LMConfig:
    base = dict(
        name=ARCH_ID + "-reduced",
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
        d_ff=96, vocab=512, pattern=("moe",),
        moe=MoEOpts(num_experts=4, top_k=1, d_ff_expert=96, shared_ff=96,
                    router_act="sigmoid", capacity_factor=4.0),
        mlp_kind="swiglu", tie_embeddings=False, dtype="float32",
        loss_chunk=64,
    )
    base.update(kw)
    return LMConfig(**base)

"""zamba2-7b [hybrid]: 81L d3584 32H (kv=32, MHA) d_ff=14336 vocab=32000,
Mamba2 backbone (ssm_state=64) + shared attention blocks.
[arXiv:2411.15242; unverified]

Deviations (docs/DESIGN.md §5): the shared attn+MLP block is applied every 9th
layer (pattern length must divide 81); weights are truly shared across
repetitions (read from outside the layer scan).  Long-context serving uses
a 4096-token sliding window on the shared-attn KV (Zamba2's trained context
is 4k) while the Mamba2 state carries unbounded context.
"""
from repro.lm.model import LMConfig
from repro.lm.ssm import SSMConfig

ARCH_ID = "zamba2-7b"


def config(**kw) -> LMConfig:
    base = dict(
        name=ARCH_ID,
        n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
        head_dim=112, d_ff=14_336, vocab=32_000,
        pattern=("mamba",) * 8 + ("shared_attn",),
        ssm=SSMConfig(d_state=64, expand=2, headdim=64, chunk=128),
        mlp_kind="swiglu", rope_theta=10_000.0, tie_embeddings=True,
        window=4096,               # shared-attn sliding window (long mode)
        long_context_ok=True,
    )
    base.update(kw)
    return LMConfig(**base)


def reduced(**kw) -> LMConfig:
    base = dict(
        name=ARCH_ID + "-reduced",
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=512,
        pattern=("mamba", "mamba", "shared_attn"),
        ssm=SSMConfig(d_state=16, expand=2, headdim=16, chunk=16),
        mlp_kind="swiglu", tie_embeddings=True, dtype="float32",
        window=64, long_context_ok=True, loss_chunk=64,
    )
    base.update(kw)
    return LMConfig(**base)

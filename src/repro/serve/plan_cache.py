"""Keyed cache of jitted serving executables with trace counters.

``jax.jit`` already memoizes by input shape, but a serving system needs
the cache to be *observable* (how many executables exist, did a request
hit a warm one) and *bounded by construction* (keys are explicit tuples —
``("plan", bucket, th, strategy)`` for the fractal partition plan,
``("serve", bucket, impl)`` for the full forward — so admission bucketing
caps the population).  The trace counter increments inside the traced
Python body, i.e. exactly once per (re)trace; tests assert one compile
per (bucket, impl) across a mixed-size request stream (DESIGN.md §9).
"""
from __future__ import annotations

import collections

import jax


class PlanCache:
    """get(key, build) -> jitted fn; build() returns the *unjitted* fn."""

    def __init__(self):
        self._fns: dict = {}
        self.hits = collections.Counter()
        self.misses = collections.Counter()
        self.traces = collections.Counter()

    def get(self, key, build):
        fn = self._fns.get(key)
        if fn is not None:
            self.hits[key] += 1
            return fn
        self.misses[key] += 1
        inner = build()

        def counted(*args):
            # Runs at trace time only: one tick per compile of this key.
            self.traces[key] += 1
            return inner(*args)

        fn = jax.jit(counted)
        self._fns[key] = fn
        return fn

    def __len__(self) -> int:
        return len(self._fns)

    def __contains__(self, key) -> bool:
        return key in self._fns

    def keys(self):
        return self._fns.keys()

    def stats(self) -> dict:
        return {"executables": len(self._fns),
                "hits": sum(self.hits.values()),
                "misses": sum(self.misses.values()),
                "traces": dict(self.traces)}

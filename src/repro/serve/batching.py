"""Per-bucket microbatching queue with a max-wait deadline (DESIGN.md §9).

Requests are FIFO within their bucket.  A bucket dispatches when it has a
full microbatch, or when its oldest pending request has waited
``max_wait_s`` (deadline flush) — partial batches are padded up to the
fixed microbatch size by the engine so the executable's shapes never vary.

The queue is deterministic and single-threaded: time enters only through
the ``now`` argument (the engine injects its clock), so tests drive the
deadline logic with a fake clock.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.serve.bucketing import BucketPolicy


@dataclasses.dataclass(frozen=True)
class Request:
    """One admitted cloud, already padded to its bucket."""

    rid: int
    coords: Any        # (bucket, 3) padded coordinates
    valid: Any         # (bucket,) bool, False on the padded tail
    n: int             # real (pre-padding) point count
    bucket: int
    t_submit: float
    dim0: int = 0      # split-dimension phase for the partition plan
                       # (scene tiles pass their tree depth % 3, §10)


@dataclasses.dataclass(frozen=True)
class MicroBatch:
    """A dispatchable unit: <= ``size`` requests of one bucket."""

    bucket: int
    requests: tuple    # tuple[Request]
    deadline_flush: bool


class MicroBatchQueue:
    """Packs pending requests into fixed-size per-bucket microbatches."""

    def __init__(self, policy: BucketPolicy, microbatch: int,
                 max_wait_s: float):
        if microbatch < 1:
            raise ValueError(f"microbatch must be >= 1, got {microbatch}")
        self.policy = policy
        self.microbatch = microbatch
        self.max_wait_s = max_wait_s
        self._pending: dict[int, list[Request]] = {
            b: [] for b in policy.buckets}
        self._next_rid = 0

    def submit(self, coords, now: float, valid=None, dim0: int = 0) -> Request:
        """Admit one cloud: bucket-pad it and enqueue.  Returns the
        Request (its ``rid`` is the completion handle)."""
        n = coords.shape[-2]
        bucket, coords, valid = self.policy.pad(coords, valid)
        req = Request(rid=self._next_rid, coords=coords, valid=valid, n=n,
                      bucket=bucket, t_submit=now, dim0=int(dim0))
        self._next_rid += 1
        self._pending[bucket].append(req)
        return req

    def pending(self, bucket: int | None = None) -> int:
        if bucket is not None:
            return len(self._pending[bucket])
        return sum(len(v) for v in self._pending.values())

    def _pop(self, bucket: int, k: int, deadline: bool) -> MicroBatch:
        reqs = tuple(self._pending[bucket][:k])
        del self._pending[bucket][:k]
        return MicroBatch(bucket=bucket, requests=reqs,
                          deadline_flush=deadline)

    def ready(self, now: float) -> list[MicroBatch]:
        """All microbatches dispatchable at ``now``: every full batch,
        plus deadline-expired partial batches (oldest request waited
        >= ``max_wait_s``)."""
        out = []
        for b, reqs in self._pending.items():
            while len(reqs) >= self.microbatch:
                out.append(self._pop(b, self.microbatch, deadline=False))
            if reqs and now - reqs[0].t_submit >= self.max_wait_s:
                out.append(self._pop(b, len(reqs), deadline=True))
        return out

    def drain(self) -> list[MicroBatch]:
        """Flush everything still pending (end of stream)."""
        out = []
        for b, reqs in self._pending.items():
            while reqs:
                k = min(len(reqs), self.microbatch)
                out.append(self._pop(b, k, deadline=k < self.microbatch))
        return out

"""Shape-bucketed admission (docs/DESIGN.md §9).

Incoming clouds have arbitrary point counts; every distinct count would be
a fresh ``jax.jit`` trace + XLA compile.  Admission therefore pads each
cloud up to the *minimal fitting* bucket from a small configured ladder
(e.g. n in {4096, 16384, 65536}) with the tail masked invalid — the same
masking contract the kernel layer uses for lane padding
(``kernels.ops.pad_points``) — so the executable cache stays bounded at
one entry per (bucket, impl) no matter what the request stream looks like.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.kernels import ops as kops

DEFAULT_BUCKETS = (4096, 16384, 65536)


def mixed_request_sizes(buckets, requests: int, seed: int = 0):
    """A representative mixed-size request stream for demos/benchmarks:
    ``n`` drawn uniformly from each bucket's full size and ~70% size, so
    every bucket sees exact fits and padded admissions."""
    sizes = sorted({n for b in buckets for n in (b, max(1, int(0.7 * b)))})
    rng = np.random.default_rng(seed)
    return [int(rng.choice(sizes)) for _ in range(requests)]


@dataclasses.dataclass(frozen=True)
class BucketPolicy:
    """An ascending ladder of admissible cloud sizes."""

    buckets: tuple = DEFAULT_BUCKETS

    def __post_init__(self):
        b = tuple(sorted(set(int(x) for x in self.buckets)))
        if not b or b[0] <= 0:
            raise ValueError(f"buckets must be positive, got {self.buckets}")
        object.__setattr__(self, "buckets", b)

    @property
    def max_points(self) -> int:
        return self.buckets[-1]

    def select(self, n: int) -> int:
        """Minimal bucket that fits an ``n``-point cloud."""
        if n <= 0:
            raise ValueError(f"need a non-empty cloud, got n={n}")
        for b in self.buckets:
            if n <= b:
                return b
        raise ValueError(f"cloud with {n} points exceeds the largest "
                         f"bucket {self.buckets[-1]}")

    def pad(self, coords, valid=None):
        """Admit one ``(p, 3)`` cloud: returns (bucket, coords', valid')
        padded to the selected bucket with the tail masked invalid."""
        bucket = self.select(coords.shape[-2])
        coords, valid = kops.pad_points(coords, bucket, valid)
        return bucket, coords, valid

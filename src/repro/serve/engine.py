"""The PNN serving engine: admission -> queue -> plan cache -> dispatch.

One engine owns the whole deployment path of docs/DESIGN.md §9:

* admission pads each cloud to its minimal shape bucket (``bucketing``);
* a per-bucket microbatch queue packs requests under a max-wait deadline
  (``batching``); partial batches are padded with all-invalid clouds so
  executable shapes never vary;
* a plan cache holds one jitted fractal-partition plan per
  (bucket, th, strategy) and one jitted forward per (bucket, impl)
  (``plan_cache``) — the plan phase is traced once per bucket, not once
  per request batch, mirroring the bppo plan/execute split (§4);
* microbatches optionally shard over an elastic mesh via ``repro.dist``
  (``elastic.make_mesh`` + ``logical.fit_specs``): clouds -> ``data``,
  fractal leaves -> ``model`` (§6).

The engine is synchronous and deterministic: time enters only through its
clock (injectable for tests), and ``warm()`` compiles every executable
up front so reported latencies never include compile time.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro import core
from repro.dist import elastic, logical
from repro.kernels import ops as kops
from repro.models import pnn
from repro.serve.batching import MicroBatch, MicroBatchQueue
from repro.serve.bucketing import DEFAULT_BUCKETS, BucketPolicy
from repro.serve.plan_cache import PlanCache


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Serving-time knobs (model structure + admission + dispatch)."""

    buckets: tuple = DEFAULT_BUCKETS
    microbatch: int = 4
    max_wait_s: float = 0.02       # deadline for partial microbatches
    variant: str = "pointnet2"     # pointnet2 | pointnext | pointvector
    task: str = "seg"              # cls | seg
    num_classes: int = 6
    th: int = 256                  # fractal threshold (plan-cache key part)
    strategy: str = "fractal"      # partition strategy (plan-cache key part)
    point_ops: str = "bppo"        # bppo | global
    impl: str | None = None        # xla | pallas | None ($REPRO_POINT_IMPL)
    leaf_chunk: int | None = None
    mesh: str = "none"             # none | auto (elastic host mesh)
    model_axis: int = 2            # elastic mesh model-axis request
    stages: tuple | None = None    # override PNNConfig.stages (scene uses
    fp_widths: tuple | None = None  # a single-SA-stage model, §10)
    on_overflow: str = "warn"      # partition-plan depth-cap overflow:
                                   # warn (async callback, ~free next to a
                                   # forward) | silent


class ServeEngine:
    """Shape-bucketed, plan-cached PNN serving (DESIGN.md §9)."""

    def __init__(self, cfg: ServeConfig, params=None, mesh=None, seed=0,
                 clock=time.monotonic):
        self.cfg = cfg
        # Pinned once: flipping $REPRO_POINT_IMPL mid-serve must not
        # bifurcate the executable cache.
        self.impl = kops.resolve_impl(cfg.impl, default="xla")
        self.policy = BucketPolicy(cfg.buckets)
        self.queue = MicroBatchQueue(self.policy, cfg.microbatch,
                                     cfg.max_wait_s)
        self.plans = PlanCache()
        self._clock = clock
        if mesh is not None:
            self.mesh = mesh
        elif cfg.mesh == "auto":
            self.mesh = elastic.make_mesh(model_axis=cfg.model_axis)
        else:
            self.mesh = None
        overrides = {k: getattr(cfg, k) for k in ("stages", "fp_widths")
                     if getattr(cfg, k) is not None}
        self._base = pnn.PNNConfig(
            name=f"serve_{cfg.variant}_{cfg.task}", variant=cfg.variant,
            task=cfg.task, num_classes=cfg.num_classes,
            n_points=self.policy.buckets[0], point_ops=cfg.point_ops,
            th=cfg.th, strategy=cfg.strategy, impl=self.impl,
            leaf_chunk=cfg.leaf_chunk, **overrides)
        self.params = (params if params is not None
                       else pnn.init(jax.random.PRNGKey(seed), self._base))
        self.results: dict[int, np.ndarray] = {}
        self._lat: dict[int, list] = {b: [] for b in self.policy.buckets}
        self.compile_s: dict[int, float] = {}
        self._t_first: float | None = None
        self._t_last: float | None = None

    # -- executables ------------------------------------------------------

    def _model_cfg(self, bucket: int) -> pnn.PNNConfig:
        return dataclasses.replace(self._base, n_points=bucket)

    def _plan_fn(self, bucket: int):
        key = ("plan", bucket, self.cfg.th, self.cfg.strategy)
        th, strategy = self.cfg.th, self.cfg.strategy
        on_overflow = self.cfg.on_overflow

        def build():
            # dim0 is a traced (B,) input, not part of the key: phasing
            # the split-dimension cycle per cloud (scene tiles) reuses the
            # one cached plan executable.  on_overflow="warn" (default)
            # surfaces depth-cap overflow in admitted clouds — e.g. an
            # unsplittable duplicate cluster bigger than th inside a
            # scene tile — via an async callback whose cost is noise next
            # to the forward it gates.
            def plan(clouds, valid, dim0):
                return jax.vmap(lambda c, v, d: core.partition(
                    c, v, th=th, strategy=strategy, dim0=d,
                    on_overflow=on_overflow))(clouds, valid, dim0)
            return plan

        return self.plans.get(key, build)

    def _serve_fn(self, bucket: int):
        key = ("serve", bucket, self.impl)
        mcfg = self._model_cfg(bucket)

        if self.cfg.point_ops == "bppo":
            def build():
                def step(params, clouds, valid, part):
                    clouds = logical.lc(clouds, "batch", "points", None)
                    valid = logical.lc(valid, "batch", "points")
                    return jax.vmap(lambda c, v, p: pnn.apply(
                        params, mcfg, c, valid=v, part0=p))(clouds, valid,
                                                            part)
                return step
        else:
            def build():
                def step(params, clouds, valid):
                    clouds = logical.lc(clouds, "batch", "points", None)
                    valid = logical.lc(valid, "batch", "points")
                    return jax.vmap(lambda c, v: pnn.apply(
                        params, mcfg, c, valid=v))(clouds, valid)
                return step

        return self.plans.get(key, build)

    def _run(self, fn, *args):
        """Call (and on first use, trace) ``fn`` under the mesh's logical
        rules so ``lc`` constraints bake into the executable."""
        if self.mesh is None:
            return fn(*args)
        with logical.logical_rules(self.mesh, logical.RULES_V0):
            return fn(*args)

    def _device_put_batch(self, clouds, valid):
        """Shard one microbatch over the mesh: clouds -> the data axes,
        specs fitted against actual shapes (non-dividing axes drop)."""
        if self.mesh is None:
            return clouds, valid
        with logical.logical_rules(self.mesh, logical.RULES_V0):
            sh = (NamedSharding(self.mesh,
                                logical.spec(("batch", "points", None))),
                  NamedSharding(self.mesh, logical.spec(("batch",
                                                         "points"))))
        sh = logical.fit_specs(sh, (clouds, valid), self.mesh)
        return jax.device_put((clouds, valid), sh)

    # -- serving ----------------------------------------------------------

    def warm(self, buckets=None) -> dict[int, float]:
        """Compile the plan + serve executables per bucket (zero-filled
        microbatch), so request latencies exclude compile.  Returns
        {bucket: compile_seconds}."""
        # Compile timing is deliberately real wall time, not self._clock():
        # an injected logical clock cannot time actual XLA compile work,
        # and compile_s is reported separately from the request-latency
        # clock domain (stats() never mixes them).
        for b in (buckets if buckets is not None else self.policy.buckets):
            t0 = time.monotonic()  # repolint: disable=CLK001
            clouds = jnp.zeros((self.queue.microbatch, b, 3), jnp.float32)
            # All-invalid clouds — the same filler _execute pads partial
            # batches with.  (All-*valid* zeros would be b duplicate
            # points: unsplittable, so every warm() would emit a spurious
            # partition-overflow warning.)
            valid = jnp.zeros((self.queue.microbatch, b), bool)
            dim0 = jnp.zeros((self.queue.microbatch,), jnp.int32)
            jax.block_until_ready(self._forward(b, clouds, valid, dim0))
            self.compile_s[b] = time.monotonic() - t0  # repolint: disable=CLK001
        return dict(self.compile_s)

    def _forward(self, bucket, clouds, valid, dim0):
        clouds, valid = self._device_put_batch(clouds, valid)
        if self.cfg.point_ops == "bppo":
            part = self._run(self._plan_fn(bucket), clouds, valid, dim0)
            return self._run(self._serve_fn(bucket), self.params, clouds,
                             valid, part)
        return self._run(self._serve_fn(bucket), self.params, clouds, valid)

    def submit(self, coords, now: float | None = None, dim0: int = 0) -> int:
        """Admit one (n, 3) cloud; returns the request id.

        ``dim0`` phases the cloud's fractal-partition plan (split dimension
        of level l is (l + dim0) % 3) — the scene executor passes each
        tile's coarse-tree depth so the tile's local tree extends the
        global one (docs/DESIGN.md §10).  It is a traced plan input, so it
        never grows the executable cache."""
        now = self._clock() if now is None else now
        coords = jnp.asarray(coords, jnp.float32)
        req = self.queue.submit(coords, now, dim0=dim0)
        if self._t_first is None:
            self._t_first = now
        return req.rid

    def step(self, now: float | None = None) -> list[int]:
        """Dispatch every microbatch that is ready at ``now`` (full, or
        past its deadline).  Returns the completed request ids.

        An injected ``now`` is threaded through to completion stamping, so
        latencies stay in the caller's clock domain (see ``_execute``)."""
        done = []
        for mb in self.queue.ready(self._clock() if now is None else now):
            done.extend(self._execute(mb, now=now))
        return done

    def flush(self, now: float | None = None) -> list[int]:
        """Drain the queue (end of stream), deadline or not."""
        done = []
        for mb in self.queue.drain():
            done.extend(self._execute(mb, now=now))
        return done

    def take(self, rid: int, default=None):
        """Pop a completed result (clients should prefer this over reading
        ``results`` directly: a long-running engine must not accumulate
        one array per request forever)."""
        return self.results.pop(rid, default)

    def _execute(self, mb: MicroBatch, now: float | None = None) -> list[int]:
        """Run one microbatch.  ``now`` is the caller-injected logical time
        (from ``step(now=)``/``flush(now=)``): when present, completions
        are stamped with it so latencies and ``wall_s`` never mix the
        injected clock domain with the engine's real clock; when absent,
        the engine clock is read *after* execution so real latencies
        include the forward."""
        bucket, reqs = mb.bucket, mb.requests
        npad = self.queue.microbatch - len(reqs)
        clouds = jnp.stack(
            [r.coords for r in reqs]
            + [jnp.zeros((bucket, 3), jnp.float32)] * npad)
        valid = jnp.stack([r.valid for r in reqs]
                          + [jnp.zeros((bucket,), bool)] * npad)
        dim0 = jnp.asarray([r.dim0 for r in reqs] + [0] * npad, jnp.int32)
        out = self._forward(bucket, clouds, valid, dim0)
        jax.block_until_ready(out)
        t_done = self._clock() if now is None else now
        out = np.asarray(out)
        rids = []
        for i, r in enumerate(reqs):
            res = out[i][:r.n] if self.cfg.task == "seg" else out[i]
            self.results[r.rid] = res
            self._lat[bucket].append((t_done - r.t_submit, r.n))
            rids.append(r.rid)
        self._t_last = t_done
        return rids

    # -- reporting --------------------------------------------------------

    def stats(self) -> dict:
        """Per-bucket latency percentiles + sustained throughput + plan
        cache counters (the BENCH_serve.json payload).

        Throughput (``wall_s``, ``clouds_per_s``, ``mpts_per_s``) is
        ``None`` until at least one microbatch has completed *and* the
        first-submit -> last-completion window has positive width: a
        submit-only stream has no window at all, and an injected clock
        can complete a batch at the very instant of its submit — either
        way, dividing by an epsilon clamp would report absurd numbers
        instead of "unknown" (benchmarks/serve_bench.py skips the None
        rows)."""
        buckets = {}
        served, points = 0, 0
        wall = None
        if (self._t_first is not None and self._t_last is not None
                and self._t_last > self._t_first):
            wall = self._t_last - self._t_first
        for b, lat in self._lat.items():
            if not lat:
                continue
            ls = np.asarray([l for l, _ in lat])
            pts = int(sum(n for _, n in lat))
            served += len(ls)
            points += pts
            buckets[b] = {
                "count": len(ls),
                "p50_ms": float(np.percentile(ls, 50) * 1e3),
                "p95_ms": float(np.percentile(ls, 95) * 1e3),
                "p99_ms": float(np.percentile(ls, 99) * 1e3),
                "mean_ms": float(ls.mean() * 1e3),
                "clouds_per_s": len(ls) / wall if wall is not None else None,
                "compile_s": self.compile_s.get(b),
            }
        return {"impl": self.impl, "served": served, "wall_s": wall,
                "clouds_per_s": served / wall if wall is not None else None,
                "mpts_per_s": (points / wall / 1e6
                               if wall is not None else None),
                "buckets": buckets, "plan_cache": self.plans.stats()}

"""repro.serve — shape-bucketed, plan-cached PNN serving (DESIGN.md §9).

Admission pads clouds to shape buckets, a per-bucket queue packs fixed
microbatches under a max-wait deadline, and a plan cache keeps exactly one
fractal-partition plan per (bucket, th, strategy) and one forward
executable per (bucket, impl).  ``examples/serve_pnn.py`` is the thin
client; ``benchmarks/serve_bench.py`` is the perf harness.
"""
from repro.serve.batching import MicroBatch, MicroBatchQueue, Request
from repro.serve.bucketing import (DEFAULT_BUCKETS, BucketPolicy,
                                   mixed_request_sizes)
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.plan_cache import PlanCache

__all__ = [
    "BucketPolicy", "DEFAULT_BUCKETS", "MicroBatch", "MicroBatchQueue",
    "PlanCache", "Request", "ServeConfig", "ServeEngine",
    "mixed_request_sizes",
]

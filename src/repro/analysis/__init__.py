"""repro.analysis — contract linter + abstract interface checker.

The repo's implicit invariants as a CI gate (docs/DESIGN.md §11):

* **lint layer** (``walker`` + ``rules``): AST passes keyed by module zone
  (``zones``) — clock-domain discipline, tracing safety, vjp completeness,
  dispatch hygiene.  Findings print as ``file:line RULE-ID severity
  message`` and are suppressible with ``# repolint: disable=RULE-ID``
  pragmas (unused pragmas are themselves findings).
* **abstract layer** (``abstract``): every public op in ``kernels/ops.py``
  run under ``jax.eval_shape`` across a shape ladder x impl matrix,
  checked against the ``kernels/ref.py`` oracle plus BlockSpec
  divisibility and a VMEM footprint budget — interface parity with zero
  kernel execution.

Run ``python -m repro.analysis --strict`` (the CI leg), or lint specific
files: ``python -m repro.analysis path/to/file.py``.
"""
from repro.analysis.report import ERROR, WARN, Finding  # noqa: F401
from repro.analysis.walker import (lint_paths, lint_source,  # noqa: F401
                                   lint_tree)
from repro.analysis.zones import (RULE_DOC, RULE_SEVERITY,  # noqa: F401
                                  RULE_ZONES, zone_of)

__all__ = ["Finding", "ERROR", "WARN", "lint_source", "lint_paths",
           "lint_tree", "zone_of", "RULE_DOC", "RULE_SEVERITY",
           "RULE_ZONES"]

"""Abstract interface checks: eval_shape parity + tile budgets, no kernels.

The numeric test suite proves the kernels *compute* the right values; this
layer proves the *interfaces* agree without executing anything.  Every
public op in ``kernels/ops.py`` is run under ``jax.eval_shape`` across a
shape ladder × impl matrix and checked three ways:

* **ABS001 cross-impl parity** — the pallas and xla backends (and the
  chunked vs unchunked paths) must produce identical shape/dtype trees:
  the dispatch layer's promise that ``impl=`` is a pure performance knob.
* **ABS002 oracle conformance** — the public wrapper's outputs must match
  ``kernels/ref.py`` evaluated on unpadded lane-major inputs: the
  slice-back-to-caller-shapes half of the dispatch contract (a padded
  lane leaking into a caller shape shows up here, with no kernel run).
* **ABS003/ABS004 tile discipline** — each op's declared VMEM tiles must
  divide their padded arrays exactly (BlockSpec divisibility), respect
  f32 (8, 128) tiling on the sublane/lane axes, and fit a per-kernel
  VMEM footprint budget (~16 MiB/core on v5e-class parts, with headroom
  for compiler temporaries).

Shapes in the ladder are deliberately *not* lane-aligned (33, 65, 200 …)
so the padding/slicing contract is actually exercised.
"""
from __future__ import annotations

import dataclasses
import functools
import inspect

from repro.analysis.report import ERROR, Finding

LANE = 128
SUBLANE = 8                       # f32 min tile is (8, 128)
VMEM_BYTES = 16 * 2 ** 20         # per-core VMEM, TPU v5e class
VMEM_FILL_MAX = 0.75              # headroom for compiler temporaries
F32 = 4                           # bytes

IMPLS = ("xla", "pallas")
CHUNKS = (None, 2)

# The shape ladder: (nb blocks, block size, samples k, neighbors num,
# window w, gather rows m, channels c).  Mixed lane-misaligned sizes.
MATRIX = (
    dict(nb=1, bs=33, k=8, num=8, w=200, m=40, c=3),
    dict(nb=3, bs=65, k=16, num=8, w=128, m=64, c=35),
    dict(nb=2, bs=256, k=64, num=32, w=512, m=256, c=64),
)


def _pad(n: int, m: int) -> int:
    return n + (-n) % m


@dataclasses.dataclass(frozen=True)
class Tile:
    """One VMEM-resident buffer of a kernel grid step.

    ``ref=False`` marks a traced intermediate (one-hot / distance
    matrices): it counts toward the VMEM footprint but is exempt from the
    BlockSpec divisibility/alignment checks — Mosaic relays intermediates
    itself; only actual ref tiles carry the layout contract."""

    name: str
    array: tuple       # full (padded) array shape the grid iterates over
    block: tuple       # per-step block shape
    bytes_per_elem: int = F32
    ref: bool = True

    @property
    def nbytes(self) -> int:
        n = self.bytes_per_elem
        for d in self.block:
            n *= d
        return n


@dataclasses.dataclass(frozen=True)
class OpCase:
    """One public op's abstract interface: how to call it, what the ref
    oracle says, and which VMEM tiles its pallas kernel materializes."""

    name: str
    wrapper: object                # the kernels/ops.py public function
    make_inputs: object            # dims dict -> user-layout avals
    call: object                   # (inputs, impl, chunk) -> eval_shape out
    oracle: object                 # dims dict -> ref-oracle eval_shape out
    tiles: object                  # dims dict -> list[Tile]


def _specs(tree):
    import jax

    return jax.tree.map(lambda a: (tuple(a.shape), str(a.dtype)), tree)


def _loc(wrapper):
    """(path, line) of a public wrapper, repo-relative when possible."""
    path = inspect.getsourcefile(wrapper) or "<unknown>"
    for marker in ("src/repro/",):
        if marker in path:
            path = marker + path.split(marker, 1)[1]
    try:
        line = inspect.getsourcelines(wrapper)[1]
    except OSError:
        line = 1
    return path, line


def build_cases() -> tuple:
    """The op table.  Imported lazily so `python -m repro.analysis <file>`
    (pure lint) never pays the jax import."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops as kops
    from repro.kernels import ref as _ref

    f32, i32 = jnp.float32, jnp.int32

    def aval(shape, dtype=f32):
        return jax.ShapeDtypeStruct(shape, dtype)

    def lane_major(d):
        """Unpadded lane-major avals for the ref oracle (NB, 3, BS)."""
        return (aval((d["nb"], 3, d["bs"])), aval((d["nb"], 1, d["bs"])))

    def ev(fn, *args, **kw):
        return jax.eval_shape(functools.partial(fn, **kw), *args)

    cases = []

    # fps_blocks -----------------------------------------------------------
    cases.append(OpCase(
        name="fps_blocks", wrapper=kops.fps_blocks,
        make_inputs=lambda d: (aval((d["nb"], d["bs"], 3)),
                               aval((d["nb"], d["bs"]), jnp.bool_)),
        call=lambda inp, impl, chunk, d: ev(
            kops.fps_blocks, *inp, k=d["k"], impl=impl, chunk=chunk),
        oracle=lambda d: ev(_ref.fps_blocks, *lane_major(d), k=d["k"]),
        tiles=lambda d: [
            Tile("coords", (d["nb"], 3, _pad(d["bs"], LANE)),
                 (1, 3, _pad(d["bs"], LANE))),
            Tile("vmask", (d["nb"], 1, _pad(d["bs"], LANE)),
                 (1, 1, _pad(d["bs"], LANE))),
            Tile("mind_scratch", (1, _pad(d["bs"], LANE)),
                 (1, _pad(d["bs"], LANE))),
            Tile("idx_out", (d["nb"], d["k"]), (1, d["k"])),
        ]))

    # ball_query_blocks ----------------------------------------------------
    cases.append(OpCase(
        name="ball_query_blocks", wrapper=kops.ball_query_blocks,
        make_inputs=lambda d: (aval((d["nb"], d["k"], 3)),
                               aval((d["nb"], d["k"]), jnp.bool_),
                               aval((d["nb"], d["w"], 3)),
                               aval((d["nb"], d["w"]), jnp.bool_)),
        call=lambda inp, impl, chunk, d: ev(
            kops.ball_query_blocks, *inp, radius=0.3, num=d["num"],
            impl=impl, chunk=chunk),
        oracle=lambda d: ev(
            _ref.ball_query_blocks,
            aval((d["nb"], 3, d["k"])), aval((d["nb"], 1, d["k"])),
            aval((d["nb"], 3, d["w"])), aval((d["nb"], 1, d["w"])),
            radius=0.3, num=d["num"]),
        tiles=lambda d: [
            Tile("centers", (d["nb"], 3, _pad(d["k"], LANE)),
                 (1, 3, _pad(d["k"], LANE))),
            Tile("window", (d["nb"], 3, _pad(d["w"], LANE)),
                 (1, 3, _pad(d["w"], LANE))),
            Tile("d2_matrix", (_pad(d["k"], LANE), _pad(d["w"], LANE)),
                 (_pad(d["k"], LANE), _pad(d["w"], LANE)), ref=False),
            Tile("idx_out", (d["nb"], _pad(d["k"], LANE), d["num"]),
                 (1, _pad(d["k"], LANE), d["num"])),
            Tile("d2_out", (d["nb"], _pad(d["k"], LANE), d["num"]),
                 (1, _pad(d["k"], LANE), d["num"])),
        ]))

    # knn_blocks -----------------------------------------------------------
    cases.append(OpCase(
        name="knn_blocks", wrapper=kops.knn_blocks,
        make_inputs=lambda d: (aval((d["nb"], d["m"], 3)),
                               aval((d["nb"], d["w"], 3)),
                               aval((d["nb"], d["w"]), jnp.bool_)),
        call=lambda inp, impl, chunk, d: ev(
            kops.knn_blocks, *inp, k=3, impl=impl, chunk=chunk),
        oracle=lambda d: ev(
            _ref.knn_blocks,
            aval((d["nb"], 3, d["m"])),
            aval((d["nb"], 3, d["w"])), aval((d["nb"], 1, d["w"])), k=3),
        tiles=lambda d: [
            Tile("queries", (d["nb"], 3, _pad(d["m"], LANE)),
                 (1, 3, _pad(d["m"], LANE))),
            Tile("window", (d["nb"], 3, _pad(d["w"], LANE)),
                 (1, 3, _pad(d["w"], LANE))),
            Tile("d2_matrix", (_pad(d["m"], LANE), _pad(d["w"], LANE)),
                 (_pad(d["m"], LANE), _pad(d["w"], LANE)), ref=False),
        ]))

    # gather_blocks (forward + its scatter-add backward tiles) -------------
    cases.append(OpCase(
        name="gather_blocks", wrapper=kops.gather_blocks,
        make_inputs=lambda d: (aval((d["nb"], d["w"], d["c"])),
                               aval((d["nb"], d["m"]), i32)),
        call=lambda inp, impl, chunk, d: ev(
            kops.gather_blocks, *inp, impl=impl, chunk=chunk),
        oracle=lambda d: ev(
            _ref.gather_blocks,
            aval((d["nb"], d["w"], d["c"])), aval((d["nb"], d["m"]), i32)),
        tiles=lambda d: [
            Tile("window_feats",
                 (d["nb"], _pad(d["w"], SUBLANE), _pad(d["c"], LANE)),
                 (1, _pad(d["w"], SUBLANE), _pad(d["c"], LANE))),
            Tile("onehot", (d["m"], _pad(d["w"], SUBLANE)),
                 (d["m"], _pad(d["w"], SUBLANE)), ref=False),
            Tile("out", (d["nb"], d["m"], _pad(d["c"], LANE)),
                 (1, d["m"], _pad(d["c"], LANE))),
            # backward (scatter_add_blocks): cotangents lane-padded on M,
            # window padded to the sublane multiple.
            Tile("bwd_g", (d["nb"], _pad(d["m"], LANE), _pad(d["c"], LANE)),
                 (1, _pad(d["m"], LANE), _pad(d["c"], LANE))),
            Tile("bwd_onehot_t",
                 (_pad(d["w"], SUBLANE), _pad(d["m"], LANE)),
                 (_pad(d["w"], SUBLANE), _pad(d["m"], LANE)), ref=False),
            Tile("bwd_out",
                 (d["nb"], _pad(d["w"], SUBLANE), _pad(d["c"], LANE)),
                 (1, _pad(d["w"], SUBLANE), _pad(d["c"], LANE))),
        ]))

    # fractal_level_blocks -------------------------------------------------
    cases.append(OpCase(
        name="fractal_level_blocks", wrapper=kops.fractal_level_blocks,
        make_inputs=lambda d: (aval((d["nb"], d["bs"], 3)),
                               aval((d["nb"], d["bs"]), jnp.bool_),
                               aval((d["nb"],))),
        call=lambda inp, impl, chunk, d: ev(
            kops.fractal_level_blocks, *inp, da=0, db=1, impl=impl,
            chunk=chunk),
        oracle=lambda d: ev(
            _ref.fractal_level_blocks, *lane_major(d),
            aval((d["nb"], 1)), da=0, db=1),
        tiles=lambda d: [
            Tile("coords", (d["nb"], 3, _pad(d["bs"], LANE)),
                 (1, 3, _pad(d["bs"], LANE))),
            Tile("side_out", (d["nb"], _pad(d["bs"], LANE)),
                 (1, _pad(d["bs"], LANE))),
        ]))

    return tuple(cases)


def check_case(case: OpCase, dims: dict) -> list:
    """All abstract checks for one (op, shape-row) cell."""
    path, line = _loc(case.wrapper)

    def finding(rule, msg):
        return Finding(path=path, line=line, rule=rule, severity=ERROR,
                       message=f"{case.name}{_dims_str(dims)}: {msg}")

    out = []
    inputs = case.make_inputs(dims)

    # ABS001: impl x chunk parity.
    got = {}
    for impl in IMPLS:
        for chunk in CHUNKS:
            try:
                got[(impl, chunk)] = _specs(
                    case.call(inputs, impl, chunk, dims))
            except Exception as e:  # abstract eval itself failed
                out.append(finding(
                    "ABS001", f"eval_shape failed for impl={impl} "
                    f"chunk={chunk}: {type(e).__name__}: {e}"))
    if out:
        return out
    base = got[("xla", None)]
    for key, specs in got.items():
        if specs != base:
            out.append(finding(
                "ABS001", f"impl={key[0]} chunk={key[1]} disagrees with "
                f"impl=xla chunk=None: {specs} != {base}"))

    # ABS002: conformance with the kernels/ref.py oracle.
    oracle = _specs(case.oracle(dims))
    if base != oracle:
        out.append(finding(
            "ABS002", f"public wrapper spec {base} != ref-oracle spec "
            f"{oracle} — outputs not sliced back to caller shapes?"))

    # ABS003: BlockSpec divisibility + f32 tiling alignment.
    total = 0
    for tile in case.tiles(dims):
        total += tile.nbytes
        if not tile.ref:
            continue
        for a, b in zip(tile.array, tile.block):
            if b == 0 or a % b:
                out.append(finding(
                    "ABS003", f"tile '{tile.name}': block {tile.block} "
                    f"does not divide array {tile.array}"))
                break
        if len(tile.block) >= 2 and tile.block[-1] >= LANE and \
                tile.block[-1] % LANE:
            out.append(finding(
                "ABS003", f"tile '{tile.name}': lane axis {tile.block[-1]} "
                f"is not a multiple of {LANE}"))

    # ABS004: VMEM footprint budget.
    budget = int(VMEM_BYTES * VMEM_FILL_MAX)
    if total > budget:
        out.append(finding(
            "ABS004", f"VMEM footprint {total / 2**20:.2f} MiB exceeds "
            f"budget {budget / 2**20:.2f} MiB "
            f"({VMEM_FILL_MAX:.0%} of {VMEM_BYTES / 2**20:.0f} MiB)"))
    return out


def _dims_str(dims: dict) -> str:
    return "[" + ",".join(f"{k}={v}" for k, v in sorted(dims.items())) + "]"


def run_interface_checks(matrix=None) -> list:
    """The full op x shape matrix; returns findings (empty == parity)."""
    findings = []
    for case in build_cases():
        for dims in (matrix or MATRIX):
            findings.extend(check_case(case, dims))
    return findings

"""Zone config: which contract applies where.

The repo's invariants are zonal, not global — the injected-clock discipline
binds ``serve/`` and ``scene/`` (the subsystems whose tests drive logical
clocks), the tracing-safety rules bind the kernel layer, the vjp/dispatch
contracts bind exactly ``kernels/ops.py``.  This module maps source paths
to zone names and zone names to the rule ids that run there, so a rule pass
never needs path logic of its own.

Fixture files (and any file outside ``src/repro``) can pin their zone with
a directive comment on any line::

    # repolint: zone=serve

Rule ids, the contract each encodes, and the PR whose bug motivated it are
documented in docs/DESIGN.md §11.
"""
from __future__ import annotations

import re

from repro.analysis.report import ERROR, WARN

# Zone of src/repro/kernels/ops.py: the dispatch layer carries contracts
# (vjp classification, impl threading) that the kernel modules don't.
KERNEL_OPS = "kernels.ops"

ZONES = ("core", "kernels", KERNEL_OPS, "models", "serve", "scene", "train",
         "launch", "dist", "lm", "data", "configs", "analysis", "other")

_ALL = frozenset(ZONES)
_KERNELY = frozenset({"kernels", KERNEL_OPS})

# rule id -> zones where the pass runs.  PRG001 (unused pragma) is emitted
# by the walker itself and applies everywhere.
RULE_ZONES = {
    "CLK001": frozenset({"serve", "scene"}),
    "CLK002": _ALL,
    "CLK003": _ALL,
    "TRC001": _ALL,
    "TRC002": _KERNELY,
    "TRC003": _KERNELY,
    "VJP001": frozenset({KERNEL_OPS}),
    "DSP001": frozenset({KERNEL_OPS}),
    "DSP002": _ALL - _KERNELY,
    "PRG001": _ALL,
}

# CLK003 is a warning: time.time() outside the clock-disciplined zones is a
# style hazard (non-monotonic intervals), not a correctness bug by itself.
# --strict (the CI leg) still fails on it.
RULE_SEVERITY = {rule: (WARN if rule == "CLK003" else ERROR)
                 for rule in RULE_ZONES}

RULE_DOC = {
    "CLK001": "wall-clock call in an injected-clock zone (serve/, scene/)",
    "CLK002": "wall-clock call inside a function taking a now= parameter",
    "CLK003": "time.time() wall clock (use time.monotonic or inject a clock)",
    "TRC001": "lru_cache over parameters that are not statically hashable",
    "TRC002": "Python if/while on a traced value in a jit/kernel function",
    "TRC003": "host-side jnp/np op inside a Pallas kernel body",
    "VJP001": "public kernel op without a kernels/vjp.py classification",
    "DSP001": "dispatch hygiene: impl must default None via resolve_impl",
    "DSP002": "hardcoded impl= literal outside the kernel layer",
    "PRG001": "unused '# repolint: disable=' pragma",
    # Abstract interface checks (emitted by abstract.py, not the AST lint).
    "ABS001": "eval_shape parity break across the impl x chunk matrix",
    "ABS002": "public wrapper spec disagrees with the kernels/ref.py oracle",
    "ABS003": "declared VMEM tile violates BlockSpec divisibility/alignment",
    "ABS004": "kernel VMEM footprint exceeds the per-core budget",
}

_ZONE_DIRECTIVE = re.compile(r"#\s*repolint:\s*zone=([a-z.]+)")


def zone_of(path: str, text: str = "") -> str:
    """Classify a source path (directive comment wins over path layout)."""
    m = _ZONE_DIRECTIVE.search(text)
    if m and m.group(1) in _ALL:
        return m.group(1)
    norm = str(path).replace("\\", "/")
    if norm.endswith("src/repro/kernels/ops.py"):
        return KERNEL_OPS
    parts = norm.split("/")
    if "repro" in parts:
        i = len(parts) - 1 - parts[::-1].index("repro")  # last 'repro' seg
        if i + 1 < len(parts) - 1 and parts[i + 1] in _ALL:
            return parts[i + 1]
    return "other"


def rules_for(zone: str):
    return frozenset(r for r, zs in RULE_ZONES.items() if zone in zs)

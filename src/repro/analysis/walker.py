"""Source-tree walking, pragma handling, and suppression accounting.

The walker owns everything that is per-file rather than per-rule: finding
the tree (``src/repro/**/*.py``), parsing each file once into an AST the
rule passes share, extracting ``# repolint: disable=RULE-ID`` pragmas, and
applying them afterwards — a pragma that suppressed nothing is itself a
finding (PRG001), so stale justifications can't linger after the code they
excused is gone.

``lint_source`` lints a source *string* under a virtual path, which is what
the mutation smoke-test in tests/test_analysis.py uses to prove the linter
would have caught the PR-5 clock-mixing bug in serve/engine.py.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize
from pathlib import Path

from repro.analysis import zones
from repro.analysis.report import Finding

_PRAGMA = re.compile(r"#\s*repolint:\s*disable=([A-Z0-9_,\s]+)")


def _comments(text: str):
    """(line, comment) pairs from real COMMENT tokens — pragma text quoted
    inside docstrings must not count as a pragma."""
    try:
        for tok in tokenize.generate_tokens(io.StringIO(text).readline):
            if tok.type == tokenize.COMMENT:
                yield tok.start[0], tok.string
    except (tokenize.TokenError, IndentationError):
        return


def repo_root() -> Path:
    """The checkout root (…/src/repro/analysis/walker.py -> …)."""
    root = Path(__file__).resolve().parents[3]
    return root if (root / "src" / "repro").is_dir() else Path.cwd()


def default_tree(root: Path | None = None):
    """The lint target when no paths are given: the src/repro package."""
    root = root or repo_root()
    return sorted((root / "src" / "repro").rglob("*.py"))


@dataclasses.dataclass
class SourceFile:
    """One parsed file plus its pragma table, shared by every rule pass."""

    path: str                  # display path (repo-relative when possible)
    text: str
    tree: ast.AST
    zone: str
    pragmas: dict              # line -> set of rule ids disabled there

    @classmethod
    def parse(cls, text: str, path: str, zone: str | None = None):
        tree = ast.parse(text, filename=path)
        pragmas = {}
        comment_text = []
        for lineno, comment in _comments(text):
            comment_text.append(comment)
            m = _PRAGMA.search(comment)
            if m:
                ids = {r.strip() for r in m.group(1).split(",") if r.strip()}
                pragmas[lineno] = ids
        return cls(path=path, text=text, tree=tree,
                   zone=zone or zones.zone_of(path, "\n".join(comment_text)),
                   pragmas=pragmas)


def _display_path(path: Path, root: Path) -> str:
    try:
        return str(path.resolve().relative_to(root))
    except ValueError:
        return str(path)


def _apply_pragmas(src: SourceFile, findings):
    """Drop findings suppressed on their own line; flag unused pragmas."""
    used = set()                      # (line, rule) pairs that fired
    kept = []
    for f in findings:
        ids = src.pragmas.get(f.line, ())
        if f.rule in ids:
            used.add((f.line, f.rule))
        else:
            kept.append(f)
    for line, ids in sorted(src.pragmas.items()):
        for rule in sorted(ids):
            if (line, rule) not in used:
                kept.append(Finding(
                    path=src.path, line=line, rule="PRG001",
                    severity=zones.RULE_SEVERITY["PRG001"],
                    message=f"pragma disables {rule} but nothing on this "
                            f"line violates it — remove the stale pragma"))
    return kept


def lint_source(text: str, path: str, zone: str | None = None,
                only: frozenset | None = None):
    """Lint one source string; returns the post-suppression findings."""
    from repro.analysis import rules  # deferred: rules imports walker types

    src = SourceFile.parse(text, path, zone=zone)
    active = zones.rules_for(src.zone)
    if only is not None:
        active &= only
    return _apply_pragmas(src, rules.run_rules(src, active))


def lint_paths(paths, root: Path | None = None,
               only: frozenset | None = None):
    """Lint a list of files; returns findings across all of them."""
    root = root or repo_root()
    findings = []
    for p in paths:
        p = Path(p)
        text = p.read_text()
        findings.extend(lint_source(text, _display_path(p, root),
                                    only=only))
    return findings


def lint_tree(root: Path | None = None, only: frozenset | None = None):
    """Lint the whole src/repro package."""
    root = root or repo_root()
    return lint_paths(default_tree(root), root=root, only=only)

"""AST lint passes — one function per contract family.

Each pass takes a parsed ``SourceFile`` and yields ``Finding``s; the walker
has already decided which passes run in which zone (``zones.RULE_ZONES``)
and applies pragma suppression afterwards.  The contracts themselves (and
the PR bug that motivated each) are documented in docs/DESIGN.md §11.

The tracing-safety pass (TRC002) carries a small static-name dataflow: in a
jitted or Pallas-kernel function, names are *traced* unless they come from
``static_argnames``, shape/ndim/dtype attributes, ``len()``, literals, or
expressions built purely from those.  Branching on a traced name is the
classic "works in interpret mode, fails under jit" bug.
"""
from __future__ import annotations

import ast

from repro.analysis.report import Finding
from repro.analysis.walker import SourceFile
from repro.analysis.zones import RULE_SEVERITY

WALL_CLOCK_ATTRS = ("time", "monotonic", "perf_counter", "monotonic_ns",
                    "perf_counter_ns", "process_time")

# Hashable-by-construction annotation names lru_cache parameters may carry.
STATIC_ANNOTATIONS = ("int", "str", "bool", "float", "bytes", "tuple",
                      "frozenset", "type", "None", "Optional")

# Attribute reads that yield static (Python-level) values even on tracers.
STATIC_ATTRS = ("shape", "ndim", "dtype", "size", "sharding")

# Builtins whose result is static when every argument is static; len() is
# static unconditionally (len of a tracer is its leading dim).
STATIC_CALLS = ("int", "float", "bool", "min", "max", "abs", "range",
                "tuple", "sorted", "sum", "isinstance", "str")

# Host-side / trace-breaking calls banned inside Pallas kernel bodies.
KERNEL_BANNED_JNP = ("array", "asarray", "save", "load", "frombuffer",
                     "fromfile")
KERNEL_BANNED_JAX = ("device_put", "block_until_ready", "jit", "vmap",
                     "pmap", "eval_shape", "make_jaxpr")


def _finding(src: SourceFile, node, rule: str, message: str) -> Finding:
    return Finding(path=src.path, line=node.lineno, rule=rule,
                   severity=RULE_SEVERITY[rule], message=message)


def _dotted(node):
    """'a.b.c' for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _func_params(fn: ast.FunctionDef):
    a = fn.args
    return a.posonlyargs + a.args + a.kwonlyargs


def _walk_functions(tree):
    """Yield (fn, enclosing_chain) for every function in the module."""
    def rec(node, chain):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield child, chain
                yield from rec(child, chain + (child,))
            else:
                yield from rec(child, chain)

    yield from rec(tree, ())


# -- clock-domain rules (CLK001/CLK002/CLK003) ----------------------------

def check_clocks(src: SourceFile, active) -> list:
    """Wall-clock *calls* are the hazard; references (``clock=time.time``
    as an injectable default) are exactly the sanctioned pattern and are
    never flagged.  One call yields at most one finding — the most
    specific applicable rule wins (CLK001 > CLK002 > CLK003)."""
    # Map each call site to its innermost enclosing function chain.
    enclosing = {}
    for fn, chain in _walk_functions(src.tree):
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                cur = enclosing.get(id(node))
                if cur is None or len(cur) < len(chain) + 1:
                    enclosing[id(node)] = chain + (fn,)

    out = []
    for node in ast.walk(src.tree):
        if not isinstance(node, ast.Call):
            continue
        dotted = _dotted(node.func)
        if dotted is None or not dotted.startswith("time."):
            continue
        attr = dotted.split(".", 1)[1]
        if attr not in WALL_CLOCK_ATTRS:
            continue
        chain = enclosing.get(id(node), ())
        in_now_fn = any("now" in [p.arg for p in _func_params(f)]
                        for f in chain)
        if "CLK001" in active:
            out.append(_finding(
                src, node, "CLK001",
                f"time.{attr}() in an injected-clock zone — time must "
                f"enter through the engine clock (the PR-5 ServeEngine "
                f"clock-mixing bug class); pass now= or use self._clock"))
        elif in_now_fn and "CLK002" in active:
            out.append(_finding(
                src, node, "CLK002",
                f"time.{attr}() inside a function taking now= — use the "
                f"injected now instead of reading the wall clock"))
        elif attr == "time" and "CLK003" in active:
            out.append(_finding(
                src, node, "CLK003",
                "time.time() is not monotonic — use time.monotonic() for "
                "intervals, or pragma with a justification if a wall-clock "
                "timestamp is genuinely required"))
    return out


# -- tracing safety: lru_cache (TRC001) -----------------------------------

def _is_lru_decorator(dec) -> bool:
    target = dec.func if isinstance(dec, ast.Call) else dec
    dotted = _dotted(target) or ""
    return dotted in ("functools.lru_cache", "lru_cache", "functools.cache",
                      "cache")


def _annotation_is_static(ann) -> bool:
    if ann is None:
        return False
    if isinstance(ann, ast.Constant):       # None, or a string annotation
        if isinstance(ann.value, str):
            return all(tok.strip(" []|,.") in STATIC_ANNOTATIONS + ("",)
                       for tok in ann.value.split("|"))
        return ann.value is None
    if isinstance(ann, ast.Name):
        return ann.id in STATIC_ANNOTATIONS
    if isinstance(ann, ast.Attribute):      # e.g. typing.Optional
        return ann.attr in STATIC_ANNOTATIONS
    if isinstance(ann, ast.BinOp) and isinstance(ann.op, ast.BitOr):
        return (_annotation_is_static(ann.left)
                and _annotation_is_static(ann.right))
    if isinstance(ann, ast.Subscript):      # tuple[int, ...], Optional[str]
        return _annotation_is_static(ann.value)
    return False


def check_lru_cache(src: SourceFile, active) -> list:
    """``functools.lru_cache`` keys on argument *hash*: a JAX array (or any
    unhashable) argument either crashes or — worse, for weakref-hashable
    objects — silently pins device memory and returns stale results.  The
    machine-checkable contract: every cached parameter carries an
    annotation that is hashable by construction."""
    out = []
    for fn, _chain in _walk_functions(src.tree):
        if not any(_is_lru_decorator(d) for d in fn.decorator_list):
            continue
        if fn.args.vararg or fn.args.kwarg:
            out.append(_finding(
                src, fn, "TRC001",
                f"lru_cache on '{fn.name}' with *args/**kwargs — cached "
                f"signatures must be fully annotated static parameters"))
            continue
        for p in _func_params(fn):
            if p.arg in ("self", "cls"):
                continue
            if not _annotation_is_static(p.annotation):
                out.append(_finding(
                    src, fn, "TRC001",
                    f"lru_cache on '{fn.name}': parameter '{p.arg}' is not "
                    f"annotated with a static hashable type (int/str/bool/"
                    f"float/tuple/...) — a traced or array argument would "
                    f"poison the cache"))
    return out


# -- tracing safety: traced-value branches (TRC002) -----------------------

def _jit_static_argnames(fn: ast.FunctionDef):
    """If ``fn`` is jit-decorated, return its static_argnames (possibly
    empty); None if not jitted."""
    for dec in fn.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        dotted = _dotted(target) or ""
        if dotted in ("jax.jit", "jit"):
            names = []
            if isinstance(dec, ast.Call):
                for kw in dec.keywords:
                    if kw.arg == "static_argnames":
                        names = [e.value for e in ast.walk(kw.value)
                                 if isinstance(e, ast.Constant)
                                 and isinstance(e.value, str)]
            return tuple(names)
        if dotted in ("functools.partial", "partial") and \
                isinstance(dec, ast.Call) and dec.args:
            inner = _dotted(dec.args[0]) or ""
            if inner in ("jax.jit", "jit"):
                names = []
                for kw in dec.keywords:
                    if kw.arg == "static_argnames":
                        names = [e.value for e in ast.walk(kw.value)
                                 if isinstance(e, ast.Constant)
                                 and isinstance(e.value, str)]
                return tuple(names)
    return None


def _pallas_kernel_names(tree):
    """Names of functions passed (possibly via functools.partial) as the
    first argument to ``pl.pallas_call``."""
    direct, via_partial = set(), {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            fdot = _dotted(node.value.func) or ""
            if fdot in ("functools.partial", "partial") and node.value.args:
                inner = _dotted(node.value.args[0])
                for t in node.targets:
                    if isinstance(t, ast.Name) and inner:
                        via_partial[t.id] = inner
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        dotted = _dotted(node.func) or ""
        if not dotted.endswith("pallas_call") or not node.args:
            continue
        arg = node.args[0]
        if isinstance(arg, ast.Name):
            direct.add(via_partial.get(arg.id, arg.id))
        elif isinstance(arg, ast.Call):
            fdot = _dotted(arg.func) or ""
            if fdot in ("functools.partial", "partial") and arg.args:
                inner = _dotted(arg.args[0])
                if inner:
                    direct.add(inner)
    return direct


class _TracedFlow:
    """Minimal dataflow over one function body: which local names are
    (possibly) traced values.  Unknown constructs default to *static* —
    the pass only flags branches that provably reference a traced name,
    keeping it a CI gate without false positives."""

    def __init__(self, traced):
        self.traced = set(traced)

    def refs_traced(self, node) -> bool:
        if node is None or isinstance(node, ast.Constant):
            return False
        if isinstance(node, ast.Name):
            return node.id in self.traced
        if isinstance(node, ast.Attribute):
            if node.attr in STATIC_ATTRS:
                return False                      # x.shape is static
            return self.refs_traced(node.value)
        if isinstance(node, ast.Call):
            dotted = _dotted(node.func) or ""
            if dotted == "len":
                return False                      # len(tracer) is static
            if dotted in STATIC_CALLS:
                return any(self.refs_traced(a) for a in node.args)
            return (self.refs_traced(node.func)
                    or any(self.refs_traced(a) for a in node.args)
                    or any(self.refs_traced(k.value)
                           for k in node.keywords))
        return any(self.refs_traced(c) for c in ast.iter_child_nodes(node))

    def _bind(self, target, is_traced: bool):
        for n in ast.walk(target):
            if isinstance(n, ast.Name):
                (self.traced.add if is_traced
                 else self.traced.discard)(n.id)

    def scan(self, src, body, out):
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # Nested defs (dispatch closures, kernel helpers): their
                # parameters receive traced values at call sites we don't
                # track, so treat them as traced; statics flow in via
                # closure from the enclosing scope.
                inner = _TracedFlow(self.traced
                                    | {p.arg for p in _func_params(stmt)})
                inner.scan(src, stmt.body, out)
                continue
            if isinstance(stmt, (ast.If, ast.While)):
                if self.refs_traced(stmt.test):
                    out.append(_finding(
                        src, stmt, "TRC002",
                        f"Python {'if' if isinstance(stmt, ast.If) else 'while'}"
                        f" on a traced value inside a jit/kernel function — "
                        f"control flow must be shape-static (use lax.cond/"
                        f"lax.select or hoist to a static argument)"))
                self.scan(src, stmt.body, out)
                self.scan(src, getattr(stmt, "orelse", []), out)
                continue
            if isinstance(stmt, ast.Assign):
                t = self.refs_traced(stmt.value)
                for target in stmt.targets:
                    self._bind(target, t)
            elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
                self._bind(stmt.target, self.refs_traced(stmt.value))
            elif isinstance(stmt, ast.AugAssign):
                if self.refs_traced(stmt.value):
                    self._bind(stmt.target, True)
            elif isinstance(stmt, ast.For):
                self._bind(stmt.target, self.refs_traced(stmt.iter))
                self.scan(src, stmt.body, out)
                self.scan(src, stmt.orelse, out)
            elif isinstance(stmt, ast.With):
                self.scan(src, stmt.body, out)
            elif isinstance(stmt, ast.Try):
                for blk in (stmt.body, stmt.orelse, stmt.finalbody):
                    self.scan(src, blk, out)
                for h in stmt.handlers:
                    self.scan(src, h.body, out)


def check_traced_branches(src: SourceFile, active) -> list:
    """TRC002 over every jit-decorated function and Pallas kernel body."""
    kernels = _pallas_kernel_names(src.tree)
    out = []
    for fn, chain in _walk_functions(src.tree):
        if chain:
            continue                       # nested defs handled by scan()
        statics = _jit_static_argnames(fn)
        if statics is not None:
            traced = {p.arg for p in _func_params(fn)
                      if p.arg not in statics}
        elif fn.name in kernels:
            # Kernel body: positional params are Refs (traced); kw-only
            # params are bound statically via functools.partial.
            traced = {p.arg for p in
                      fn.args.posonlyargs + fn.args.args}
        else:
            continue
        _TracedFlow(traced).scan(src, fn.body, out)
    return out


# -- tracing safety: host-side ops in kernel bodies (TRC003) --------------

def check_kernel_host_ops(src: SourceFile, active) -> list:
    """Pallas kernel bodies run on-core: host numpy and host-side jax ops
    (device_put, block_until_ready, nested jit, ...) cannot appear there,
    and device constants must not be materialized inside the body (plain
    Python scalars + iota only — see kernels/common.py)."""
    kernels = _pallas_kernel_names(src.tree)
    out = []
    for fn, _chain in _walk_functions(src.tree):
        if fn.name not in kernels:
            continue
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            dotted = _dotted(node.func) or ""
            parts = dotted.split(".")
            if parts[0] in ("np", "numpy") and len(parts) > 1:
                out.append(_finding(
                    src, node, "TRC003",
                    f"host numpy call '{dotted}' inside Pallas kernel "
                    f"'{fn.name}' — kernel bodies are traced on-core; use "
                    f"jnp/lax on ref values"))
            elif parts[0] == "jnp" and len(parts) == 2 and \
                    parts[1] in KERNEL_BANNED_JNP:
                out.append(_finding(
                    src, node, "TRC003",
                    f"'{dotted}' inside Pallas kernel '{fn.name}' — kernel "
                    f"bodies may not materialize/capture host arrays "
                    f"(kernels/common.py: plain Python scalars only)"))
            elif parts[0] == "jax" and len(parts) == 2 and \
                    parts[1] in KERNEL_BANNED_JAX:
                out.append(_finding(
                    src, node, "TRC003",
                    f"host-side '{dotted}' inside Pallas kernel "
                    f"'{fn.name}'"))
    return out


# -- vjp completeness + dispatch hygiene (VJP001/DSP001) ------------------

def _public_op_wrappers(tree):
    """Module-level public functions with a keyword-only ``impl`` param —
    the dispatch layer's op-wrapper signature."""
    for node in ast.iter_child_nodes(tree):
        if isinstance(node, ast.FunctionDef) and \
                not node.name.startswith("_") and \
                any(p.arg == "impl" for p in node.args.kwonlyargs):
            yield node


def _vjp_factories(tree):
    """Names of module functions whose body returns a kernels/vjp.py
    classification (``index_producer`` / ``gathering``)."""
    names = set()
    for fn, _chain in _walk_functions(tree):
        for node in ast.walk(fn):
            if isinstance(node, ast.Return) and \
                    isinstance(node.value, ast.Call):
                dotted = _dotted(node.value.func) or ""
                if dotted.split(".")[-1] in ("index_producer", "gathering"):
                    names.add(fn.name)
    return names


def check_vjp_completeness(src: SourceFile, active) -> list:
    """Every public op must route through a classified custom_vjp factory:
    new ops cannot silently ship forward-only (the gap PR 5 closed)."""
    factories = _vjp_factories(src.tree)
    out = []
    for fn in _public_op_wrappers(src.tree):
        calls = {(_dotted(n.func) or "").split(".")[-1]
                 for n in ast.walk(fn) if isinstance(n, ast.Call)}
        if not (calls & factories) and \
                not (calls & {"index_producer", "gathering"}):
            out.append(_finding(
                src, fn, "VJP001",
                f"public op '{fn.name}' is not classified via "
                f"kernels/vjp.py (index_producer | gathering) — it would "
                f"ship without a backward contract"))
    return out


def check_dispatch_hygiene(src: SourceFile, active) -> list:
    """DSP001: public ops take ``impl=None`` and resolve it through
    ``resolve_impl`` (explicit arg > $REPRO_POINT_IMPL > default) — a
    hardcoded default would bifurcate the executable cache."""
    out = []
    for fn in _public_op_wrappers(src.tree):
        kw = {p.arg: d for p, d in
              zip(fn.args.kwonlyargs, fn.args.kw_defaults)}
        d = kw.get("impl")
        if not (isinstance(d, ast.Constant) and d.value is None):
            out.append(_finding(
                src, fn, "DSP001",
                f"public op '{fn.name}': impl= must default to None "
                f"(resolved via resolve_impl), not a hardcoded backend"))
        calls = {(_dotted(n.func) or "").split(".")[-1]
                 for n in ast.walk(fn) if isinstance(n, ast.Call)}
        if fn.name != "resolve_impl" and "resolve_impl" not in calls:
            out.append(_finding(
                src, fn, "DSP001",
                f"public op '{fn.name}' does not route impl through "
                f"resolve_impl() — env-default resolution must happen "
                f"eagerly in the wrapper, before the jitted inner fn"))
    return out


def check_impl_literals(src: SourceFile, active) -> list:
    """DSP002: outside the kernel layer, ``impl=`` must thread from config
    (PNNConfig / ServeConfig / CLI), never a hardcoded string literal —
    a literal pins one backend and splits it from the executable-cache
    key discipline."""
    out = []
    for node in ast.walk(src.tree):
        if not isinstance(node, ast.Call):
            continue
        for kwarg in node.keywords:
            if kwarg.arg == "impl" and isinstance(kwarg.value, ast.Constant) \
                    and isinstance(kwarg.value.value, str):
                out.append(_finding(
                    src, node, "DSP002",
                    f"hardcoded impl={kwarg.value.value!r} — thread the "
                    f"backend from config instead of pinning it at the "
                    f"call site"))
    return out


# -- registry --------------------------------------------------------------

# pass -> the rule ids it can emit (a pass runs iff any of them is active).
_PASSES = (
    (check_clocks, ("CLK001", "CLK002", "CLK003")),
    (check_lru_cache, ("TRC001",)),
    (check_traced_branches, ("TRC002",)),
    (check_kernel_host_ops, ("TRC003",)),
    (check_vjp_completeness, ("VJP001",)),
    (check_dispatch_hygiene, ("DSP001",)),
    (check_impl_literals, ("DSP002",)),
)


def run_rules(src: SourceFile, active: frozenset) -> list:
    findings = []
    for fn, rules in _PASSES:
        if any(r in active for r in rules):
            findings.extend(f for f in fn(src, active)
                            if f.rule in active)
    return findings

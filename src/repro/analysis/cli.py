"""CLI: ``python -m repro.analysis [paths ...] [--strict] [...]``.

With no paths, lints the whole ``src/repro`` tree and runs the abstract
interface matrix (eval_shape only — no kernel executes); with explicit
paths, lints just those files (fixtures, pre-commit hooks) and skips the
abstract layer unless ``--abstract`` is passed.  Exit code 0 when clean,
1 when findings fail (errors always; warnings too under ``--strict``).
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis import report, walker, zones


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="FractalCloud contract linter + abstract interface "
                    "checker (rule docs: docs/DESIGN.md §11)")
    ap.add_argument("paths", nargs="*",
                    help="files to lint (default: the src/repro tree)")
    ap.add_argument("--strict", action="store_true",
                    help="warnings fail too (the CI gate mode)")
    ap.add_argument("--abstract", dest="abstract", action="store_true",
                    default=None, help="force the eval_shape interface "
                    "matrix on (default: on for tree runs, off for "
                    "explicit paths)")
    ap.add_argument("--no-abstract", dest="abstract", action="store_false",
                    help="skip the eval_shape interface matrix")
    ap.add_argument("--rules", default=None,
                    help="comma list of rule ids to run (default: all)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule table and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in sorted(zones.RULE_DOC):
            sev = zones.RULE_SEVERITY.get(rule, report.ERROR)
            print(f"{rule}  {sev:5s}  {zones.RULE_DOC[rule]}")
        return 0

    only = (frozenset(r.strip() for r in args.rules.split(","))
            if args.rules else None)
    run_abstract = args.abstract
    if run_abstract is None:
        run_abstract = not args.paths

    if args.paths:
        findings = walker.lint_paths(args.paths, only=only)
    else:
        findings = walker.lint_tree(only=only)
    if run_abstract:
        from repro.analysis import abstract

        findings += abstract.run_interface_checks()

    findings = report.sort_findings(findings)
    for f in findings:
        print(f.format())
    print(report.summarize(findings), file=sys.stderr)
    return 1 if report.failed(findings, strict=args.strict) else 0


if __name__ == "__main__":
    sys.exit(main())

"""Findings: the one record type every analysis layer emits.

Both the AST lint rules (``rules.py``) and the abstract interface checks
(``abstract.py``) report through this module, so the CLI, CI leg, and tests
see a single stream of ``file:line RULE-ID severity message`` lines no
matter which layer produced them.
"""
from __future__ import annotations

import dataclasses

ERROR = "error"
WARN = "warn"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One contract violation at a source location."""

    path: str          # repo-relative where possible
    line: int
    rule: str          # e.g. "CLK001"
    severity: str      # ERROR | WARN
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line} {self.rule} {self.severity} " \
               f"{self.message}"


def sort_findings(findings):
    """Stable report order: by file, then line, then rule id."""
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


def summarize(findings) -> str:
    errors = sum(1 for f in findings if f.severity == ERROR)
    warns = len(findings) - errors
    if not findings:
        return "repro.analysis: clean"
    return (f"repro.analysis: {len(findings)} finding(s) "
            f"({errors} error(s), {warns} warning(s))")


def failed(findings, strict: bool = False) -> bool:
    """Exit-code policy: errors always fail; warnings fail under --strict."""
    if any(f.severity == ERROR for f in findings):
        return True
    return strict and bool(findings)

"""PNN dry-run cells — the paper's own workloads on the production mesh.

By default the cell lowers a *serving* step (the paper is an inference
accelerator): Fractal partition -> BPPO point ops -> PNN feature stages,
for PointNeXt segmentation at S3DIS scale (33K / 289K points, paper
Figs. 13/15/18).  With ``kind="train"`` it lowers the *fine-tune* step
instead — ``jax.value_and_grad`` through the same pipeline plus the AdamW
update (the execute-phase VJPs of kernels/vjp.py make this valid for
either impl) — proving the backward pass compiles at production scale.
Sharding: clouds -> ``data``, fractal leaves -> ``model`` (the paper's
inter-block parallelism promoted to chips; docs/DESIGN.md §6).

Called from dryrun.py via ``--arch pointnext --shape pnn_289k``
(``--train`` for the train cell).
"""
from __future__ import annotations

import dataclasses
import math
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import logical
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.models import pnn
from repro.train import optimizer as opt_lib


@dataclasses.dataclass(frozen=True)
class PNNShape:
    name: str
    n_points: int
    batch: int
    th: int


PNN_SHAPES = {
    "pnn_33k": PNNShape("pnn_33k", 33_000, 16, 256),
    "pnn_289k": PNNShape("pnn_289k", 289_000, 16, 256),
    "pnn_1m": PNNShape("pnn_1m", 1_000_000, 4, 256),
}

PNN_VARIANTS = {
    "pointnet2": pnn.pointnet2_seg,
    "pointnext": pnn.pointnext_seg,
    "pointvector": pnn.pointvector_seg,
}


def _model_flops(cfg: pnn.PNNConfig, n: int, batch: int, params) -> float:
    """Useful FLOPs: MLP matmuls over grouped features + point-op distance
    updates (3 mul + 3 add per pair)."""
    total = 0.0
    sizes = cfg.stage_sizes()
    c_in = cfg.in_channels
    for i, s in enumerate(cfg.stages):
        m = sizes[i + 1]
        widths = (c_in + 3,) + tuple(s.widths)
        for a, b in zip(widths[:-1], widths[1:]):
            total += 2.0 * m * s.nsample * a * b
        # FPS within blocks: k iterations x block size; BQ: centers x window
        total += 6.0 * sizes[i] * (s.rate * cfg.th) + \
            6.0 * m * s.nsample * 2 * cfg.th
        c_in = s.widths[-1]
    for widths in cfg.fp_widths:
        m = sizes[-1]
        for a, b in zip((c_in,) + tuple(widths)[:-1], widths):
            total += 2.0 * m * a * b
    return total * batch


def run_pnn_cell(variant: str, shape_name: str, *, multi_pod: bool = False,
                 verbose: bool = True, rules=None, leaf_chunk: int = 512,
                 point_ops: str = "bppo", impl: str | None = None,
                 batch: int | None = None, kind: str = "serve"):
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    chips = mesh.devices.size
    shape = PNN_SHAPES[shape_name]
    if batch is not None:
        shape = dataclasses.replace(shape, batch=batch)
    cfg = PNN_VARIANTS[variant](n=shape.n_points, point_ops=point_ops,
                                th=shape.th, impl=impl)
    cfg = dataclasses.replace(cfg, leaf_chunk=leaf_chunk)

    t0 = time.monotonic()
    params = jax.eval_shape(
        lambda: pnn.init(jax.random.PRNGKey(0), cfg))
    clouds = jax.ShapeDtypeStruct((shape.batch, shape.n_points, 3),
                                  jnp.float32)

    def serve_step(params, clouds):
        return jax.vmap(lambda c: pnn.apply(params, cfg, c))(clouds)

    rules = rules or logical.RULES_V0
    batch_axes = rules.get("batch", ("pod", "data"))
    batch_axes = tuple(a for a in (batch_axes or ())
                       if a in mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    # jit argument shardings must divide evenly: drop axes until they do.
    while batch_axes and shape.batch % \
            math.prod(sizes[a] for a in batch_axes):
        batch_axes = batch_axes[1:]
    cloud_sh = NamedSharding(
        mesh, P(batch_axes) if batch_axes else P())
    with logical.logical_rules(mesh, rules):
        if kind == "train":
            from repro.train.pnn import train_step_fn
            labels = jax.ShapeDtypeStruct(
                (shape.batch,) + ((shape.n_points,)
                                  if cfg.task == "seg" else ()), jnp.int32)
            opt_shapes = jax.eval_shape(opt_lib.init, params)
            label_sh = NamedSharding(
                mesh, P(batch_axes) if batch_axes else P())
            # The exact step the trainer runs (train/pnn.py), lowered with
            # the cell's shardings instead of its jit.
            train_step = train_step_fn(cfg, opt_lib.OptConfig(warmup=0))
            b_sh = {"points": cloud_sh, "labels": label_sh}
            lowered = jax.jit(
                train_step, in_shardings=(None, None, b_sh),
                out_shardings=(None, None, None)).lower(
                    params, opt_shapes, {"points": clouds,
                                         "labels": labels})
        else:
            lowered = jax.jit(serve_step, in_shardings=(None, cloud_sh),
                              out_shardings=cloud_sh).lower(params, clouds)
        compiled = lowered.compile()

    model_flops = _model_flops(cfg, shape.n_points, shape.batch, params)
    if kind == "train":
        model_flops *= 3.0  # fwd + bwd, the usual 1:2 convention
    row = rl.analyze(compiled, arch=variant,
                     shape=f"{shape_name}_train" if kind == "train"
                     else shape_name,
                     mesh_name=mesh_name, chips=chips,
                     model_flops=model_flops)
    d = row.to_dict()
    d["compile_s"] = time.monotonic() - t0
    d["kind"] = kind
    if verbose:
        mem = d["mem_per_device"]
        print(f"[dryrun:pnn] {variant} x {shape_name} on {mesh_name}: "
              f"peak {mem['peak_mb']/1024:.2f} GB/device | "
              f"flops/chip {d['hlo_flops_per_chip']:.3e} | "
              f"coll {d['coll_bytes_per_chip']/2**20:.1f} MB | "
              f"bound={d['bottleneck']} | compile {d['compile_s']:.0f}s",
              flush=True)
    return d

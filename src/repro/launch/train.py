"""Distributed training launcher.

Wires mesh selection (elastic), logical sharding rules, the jitted train
step, the fault-tolerant loop (checkpoint/restart, straggler monitor,
optional gradient compression) and the resumable synthetic data pipeline.

On this CPU container it runs reduced configs on host devices; on a real
pod the same entrypoint runs the full config (the dry-run proves those
compile). Examples:

  # LM pretraining smoke on whatever devices exist:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --steps 50 --batch 4 --seq 64 --ckpt /tmp/ck

  # resume after a crash: rerun the same command (restores latest step)
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.dist import elastic, logical
from repro.lm import model as M
from repro.lm import steps as steps_lib
from repro.train import loop as loop_lib
from repro.train import optimizer as opt_lib


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m",
                    choices=sorted(configs.ARCHS))
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (pod-scale) config instead of the "
                         "reduced one")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--compression", default="none",
                    choices=["none", "bf16", "int8"])
    ap.add_argument("--model-axis", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (configs.lm_config(args.arch) if args.full_config
           else configs.lm_reduced(args.arch))
    mesh = elastic.make_mesh(model_axis=args.model_axis)
    print(f"[train] mesh {dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"({mesh.devices.size} devices), arch {cfg.name}")
    rules = logical.RULES_V0
    opt_cfg = opt_lib.OptConfig(lr=args.lr, warmup=min(10, args.steps // 5),
                                total_steps=args.steps)

    def init_params():
        params, axes = M.init(jax.random.PRNGKey(args.seed), cfg)
        specs = logical.fit_specs(
            logical.param_specs(axes, mesh, rules), params, mesh)
        return jax.device_put(params, specs)

    b_sh = NamedSharding(mesh, P(tuple(
        a for a in ("pod", "data") if a in mesh.axis_names), ))

    def next_batch(step):
        key = jax.random.fold_in(jax.random.PRNGKey(args.seed + 7), step)
        toks = jax.random.randint(key, (args.batch, args.seq), 0, cfg.vocab)
        labels = (toks * 7 + jnp.arange(args.seq)[None, :]) % cfg.vocab
        batch = {"labels": labels}
        if cfg.encoder_layers or cfg.frontend == "embeddings":
            batch["frames"] = jax.random.normal(
                key, (args.batch, args.seq, cfg.d_model)) * 0.1
            if cfg.encoder_layers:
                batch["dec_tokens"] = toks
        else:
            batch["tokens"] = toks
        sh = {k: b_sh if v.ndim == 2 else NamedSharding(
            mesh, P(b_sh.spec[0], None, None))
            for k, v in batch.items()}
        return jax.device_put(batch, logical.fit_specs(sh, batch, mesh))

    base = steps_lib.make_train_step(cfg, opt_cfg,
                                     microbatch=args.microbatch)
    jitted = jax.jit(base)
    grad_fn = jax.jit(jax.value_and_grad(
        lambda p, b: steps_lib.loss_fn(p, cfg, b)[0]))

    def train_step(params, opt_state, batch, return_grads=False):
        with logical.logical_rules(mesh, rules):
            if return_grads:
                loss, grads = grad_fn(params, batch)
                return grads, {"loss": loss}
            return jitted(params, opt_state, batch)

    loop_cfg = loop_lib.LoopConfig(
        total_steps=args.steps, ckpt_dir=args.ckpt,
        ckpt_every=args.ckpt_every, log_every=10,
        grad_compression=args.compression, seed=args.seed)
    params, _, info = loop_lib.run(
        loop_cfg, init_params=init_params, train_step=train_step,
        next_batch=next_batch, opt_cfg=opt_cfg)
    h = info["history"]
    if h:
        print(f"[train] done: loss {h[0]['loss']:.4f} -> "
              f"{h[-1]['loss']:.4f}; {info['monitor']}")
    else:
        print("[train] nothing to do: checkpoint already at "
              f"step >= {args.steps}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Production mesh definitions (TPU v5e pods).

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state): single-pod (16, 16) ("data", "model") = v5e-256; with
``multi_pod=True`` (2, 16, 16) ("pod", "data", "model") = 2 pods / 512
chips.  Elastic variants live in repro/dist/elastic.py.
"""
from __future__ import annotations

import jax

from repro.dist.compat import AxisType, make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes,
                     axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(model_axis: int = 2):
    """Tiny mesh over whatever local devices exist (tests)."""
    n = len(jax.devices())
    model_axis = min(model_axis, n)
    while n % model_axis:
        model_axis -= 1
    return make_mesh((n // model_axis, model_axis), ("data", "model"),
                     axis_types=(AxisType.Auto, AxisType.Auto))

"""LM serving launcher: continuous batched greedy decoding.

Prefill once per request batch, then step the decode loop with the KV /
recurrent-state caches (the same code path the decode_* dry-run cells
compile for the production mesh).

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
      --batch 2 --prompt-len 16 --max-new 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.dist import elastic, logical
from repro.lm import model as M


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m",
                    choices=sorted(configs.ARCHS))
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--model-axis", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.lm_reduced(args.arch)
    if cfg.encoder_layers:
        raise SystemExit("enc-dec serving demo: use examples/ drivers")
    mesh = elastic.make_mesh(model_axis=args.model_axis)
    params, axes = M.init(jax.random.PRNGKey(args.seed), cfg)
    params = jax.device_put(params, logical.fit_specs(
        logical.param_specs(axes, mesh, logical.RULES_V0), params, mesh))
    max_len = args.prompt_len + args.max_new
    toks = jax.random.randint(jax.random.PRNGKey(1),
                              (args.batch, args.prompt_len), 0, cfg.vocab)

    with logical.logical_rules(mesh, logical.RULES_V0):
        prefill = jax.jit(lambda p, t: M.prefill(p, cfg, tokens=t,
                                                 max_len=max_len))
        decode = jax.jit(lambda p, t, c, pos: M.decode_step(p, cfg, t, c,
                                                            pos))
        t0 = time.monotonic()
        logits, cache = prefill(params, toks)
        jax.block_until_ready(logits)
        t_prefill = time.monotonic() - t0

        out = []
        nxt = jnp.argmax(logits, -1)
        t0 = time.monotonic()
        for i in range(args.max_new):
            out.append(nxt)
            logits, cache = decode(params, nxt, cache,
                                   jnp.int32(args.prompt_len + i))
            nxt = jnp.argmax(logits, -1)
        jax.block_until_ready(nxt)
        t_decode = time.monotonic() - t0

    gen = jnp.concatenate(out, axis=1)
    print(f"[serve] {cfg.name}: prefill {args.prompt_len} toks in "
          f"{t_prefill*1e3:.0f} ms; {args.max_new} decode steps in "
          f"{t_decode*1e3:.0f} ms "
          f"({args.max_new * args.batch / max(t_decode, 1e-9):.1f} tok/s)")
    print(f"[serve] generated ids: {gen.tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

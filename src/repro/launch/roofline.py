"""Roofline terms from compiled dry-run artifacts (TPU v5e targets).

compute term    = HLO_FLOPs_per_partition / peak_FLOPs
memory term     = HLO_bytes_per_partition / HBM_bw
collective term = per-partition collective wire bytes / ICI_bw

``cost_analysis()`` on the SPMD-partitioned module is per-partition
(verified empirically: global/chips), so terms are per-chip seconds
directly; the spec's global formulation (X/(chips*peak)) is identical.
Collective bytes are parsed from ``compiled.as_text()`` with per-op wire
factors (ring all-reduce moves ~2x(n-1)/n of the payload, etc.).
"""
from __future__ import annotations

import dataclasses
import json
import re

import jax.numpy as jnp

# TPU v5e per-chip constants (assignment-specified).
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link (~effective per-chip)

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
                "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\S+))\s+"
    r"(all-reduce-start|all-gather-start|reduce-scatter|all-to-all|"
    r"collective-permute-start|all-reduce|all-gather|collective-permute)"
    r"\(")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


# Wire factors: fraction of the (result) payload each chip actually moves.
_WIRE_FACTOR = {
    "all-reduce": 2.0,          # reduce-scatter + all-gather ring
    "all-reduce-start": 2.0,
    "all-gather": 1.0,
    "all-gather-start": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
    "collective-permute-start": 1.0,
}


def parse_collectives(hlo_text: str):
    """Per-partition wire bytes by collective kind."""
    by_kind: dict[str, float] = {}
    count: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape, kind = m.group(1), m.group(2)
        base = kind.replace("-start", "")
        nbytes = _shape_bytes(shape) * _WIRE_FACTOR[kind]
        by_kind[base] = by_kind.get(base, 0.0) + nbytes
        count[base] = count.get(base, 0) + 1
    return by_kind, count


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float            # per-partition
    hlo_bytes: float            # per-partition
    coll_bytes: float           # per-partition wire bytes
    coll_by_kind: dict
    coll_count: dict
    model_flops: float          # useful (global) flops
    mem_per_device: dict

    @property
    def t_compute(self):
        return self.hlo_flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self):
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self):
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self):
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def usefulness(self):
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self):
        """useful-FLOPs time / achievable step time (dominant term)."""
        t_star = self.model_flops / (self.chips * PEAK_FLOPS_BF16)
        t = max(self.t_compute, self.t_memory, self.t_collective)
        return t_star / t if t else 0.0

    def to_dict(self):
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops_per_chip": self.hlo_flops,
            "hlo_bytes_per_chip": self.hlo_bytes,
            "coll_bytes_per_chip": self.coll_bytes,
            "coll_by_kind": self.coll_by_kind,
            "coll_count": self.coll_count,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "usefulness": self.usefulness,
            "roofline_fraction": self.roofline_fraction,
            "mem_per_device": self.mem_per_device,
        }


def analyze(compiled, *, arch, shape, mesh_name, chips, model_flops):
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older jax: one dict per device
        ca = ca[0] if ca else {}
    hlo_flops = float(ca.get("flops", 0.0))
    hlo_bytes = float(ca.get("bytes accessed", 0.0))
    by_kind, count = parse_collectives(compiled.as_text())
    ma = compiled.memory_analysis()
    mem = {
        "argument_mb": ma.argument_size_in_bytes / 2**20,
        "output_mb": ma.output_size_in_bytes / 2**20,
        "temp_mb": ma.temp_size_in_bytes / 2**20,
        "peak_mb": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                    + ma.temp_size_in_bytes) / 2**20,
    }
    return Roofline(arch=arch, shape=shape, mesh=mesh_name, chips=chips,
                    hlo_flops=hlo_flops, hlo_bytes=hlo_bytes,
                    coll_bytes=sum(by_kind.values()), coll_by_kind=by_kind,
                    coll_count=count, model_flops=model_flops,
                    mem_per_device=mem)


# ---------------------------------------------------------------------------
# MODEL_FLOPS (useful work) estimators
# ---------------------------------------------------------------------------

def count_params(shapes_tree):
    import jax
    total = 0
    for leaf in jax.tree.leaves(shapes_tree):
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
    return total


def active_params(cfg, shapes_tree):
    """Params touched per token: MoE experts scaled by top_k/num_experts."""
    import jax
    total, expert, expert_active = 0, 0, 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes_tree)[0]:
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        if cfg.moe and "ffn" in keys and any(
                k in ("wi", "wg", "wo") for k in keys) and leaf.ndim >= 3:
            expert += n
            expert_active += n * cfg.moe.top_k / cfg.moe.num_experts
    return total - expert + expert_active


def model_flops_for(cfg, shape_kind: str, seq: int, batch: int,
                    n_active: float) -> float:
    """Useful FLOPs of one step (global). Sliding-window (local) layers
    only attend over min(window, context)."""
    tokens = batch * seq

    def att_ctx(kind, s):
        if kind in ("local", "shared_attn") and cfg.window:
            return min(cfg.window, s)
        return s

    att_kinds = [k for k in cfg.pattern
                 if k in ("attn", "local", "moe", "shared_attn", "xattn")]
    h_hd = cfg.n_heads * cfg.hd
    if shape_kind == "train":
        dense = 6.0 * n_active * tokens
        att = sum(3.0 * 4.0 * h_hd * cfg.reps * batch * seq
                  * (att_ctx(k, seq) / 2) for k in att_kinds)
        return dense + att
    if shape_kind == "prefill":
        dense = 2.0 * n_active * tokens
        att = sum(4.0 * h_hd * cfg.reps * batch * seq
                  * (att_ctx(k, seq) / 2) for k in att_kinds)
        return dense + att
    # decode: one token per sequence in the batch
    dense = 2.0 * n_active * batch
    att = sum(4.0 * h_hd * cfg.reps * batch * att_ctx(k, seq)
              for k in att_kinds)
    return dense + att


def format_table(rows):
    head = (f"{'arch':22s} {'shape':12s} {'mesh':9s} "
            f"{'t_comp':>9s} {'t_mem':>9s} {'t_coll':>9s} "
            f"{'bound':>6s} {'useful':>7s} {'roofline':>8s} {'peakGB':>7s}")
    lines = [head, "-" * len(head)]
    for r in rows:
        d = r.to_dict() if isinstance(r, Roofline) else r
        lines.append(
            f"{d['arch']:22s} {d['shape']:12s} {d['mesh']:9s} "
            f"{d['t_compute_s']*1e3:8.2f}m {d['t_memory_s']*1e3:8.2f}m "
            f"{d['t_collective_s']*1e3:8.2f}m {d['bottleneck'][:6]:>6s} "
            f"{d['usefulness']*100:6.1f}% {d['roofline_fraction']*100:7.1f}% "
            f"{d['mem_per_device']['peak_mb']/1024:6.2f}")
    return "\n".join(lines)

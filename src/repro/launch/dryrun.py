import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this jits the real step function (train_step / prefill /
decode) with in/out shardings derived from the logical-axes trees, compiles
it for the production mesh built from 512 placeholder host devices, prints
``memory_analysis()`` (fits/doesn't) and ``cost_analysis()`` (FLOPs/bytes),
parses the collective schedule, and emits a roofline JSON row.

Usage:
  python -m repro.launch.dryrun --arch gemma3-12b --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod --out results.json
  python -m repro.launch.dryrun --arch pointnext --shape pnn_289k  # PNN cell
  python -m repro.launch.dryrun --arch pointnext --shape pnn_33k --train
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.shapes import SHAPES, applicable
from repro.dist import logical
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.lm import model as M
from repro.lm import steps as steps_lib
from repro.train import optimizer as opt_lib

BATCH_AXES = {
    "tokens": ("batch", None), "labels": ("batch", None),
    "dec_tokens": ("batch", None), "loss_mask": ("batch", None),
    "frames": ("batch", None, "embed"),
}


def _shardings_for_axes(axes_tree, mesh, rules=None):
    return logical.param_specs(axes_tree, mesh, rules)


# Shared spec-fitting lives in dist.logical; keep the local names this
# module's call sites were built against.
_axis_size = logical.entry_size
_fit_shardings = logical.fit_specs


def _rules_for(shape, mesh):
    """Cell-adapted rules: small-batch decode drops batch sharding and
    spreads the KV/cache sequence over both axes instead."""
    rules = dict(logical.RULES_V0)
    dp = _axis_size(mesh, tuple(a for a in ("pod", "data")
                                if a in mesh.axis_names))
    if shape.kind == "decode" and shape.global_batch % dp:
        rules["batch"] = None
        rules["kv_seq"] = ("pod", "data", "model")
    return rules


def _batch_shardings(specs, mesh, rules):
    ctx = logical._Ctx(mesh, rules)

    def one(path, leaf):
        name = str(path[-1].key)
        ax = BATCH_AXES[name]
        return NamedSharding(
            mesh, P(*[logical._axis_to_mesh(ctx, a) for a in ax]))

    flat = jax.tree_util.tree_flatten_with_path(specs)
    leaves = [one(p, l) for p, l in flat[0]]
    return jax.tree_util.tree_unflatten(flat[1], leaves)


# Gradient-accumulation policy for train cells: activation memory control
# at fixed global batch (metric compiles use 1; total FLOPs are unchanged).
MICROBATCH = {"llama4-scout-17b-16e": 4, "chameleon-34b": 4,
              "zamba2-7b": 4, "gemma3-12b": 4, "minitron-4b": 2,
              "gemma2-2b": 2, "granite-moe-3b-a800m": 8, "xlstm-1.3b": 4}


def _compile_cell(cfg, shape, mesh, rules, opt_overrides=None,
                  microbatch=1):
    """Lower + compile one step function; returns the compiled object."""
    param_shapes, axes = steps_lib.eval_shape_init(cfg)
    p_sh = _fit_shardings(_shardings_for_axes(axes, mesh, rules),
                          param_shapes, mesh)
    with logical.logical_rules(mesh, rules):
        if shape.kind == "train":
            opt_cfg = opt_lib.OptConfig(**(opt_overrides or {}))
            step = steps_lib.make_train_step(cfg, opt_cfg,
                                             microbatch=microbatch)
            batch_specs = steps_lib.batch_specs(cfg, shape)
            opt_shapes = jax.eval_shape(opt_lib.init, param_shapes)
            o_sh = _fit_shardings(
                logical.param_specs(opt_lib.init_axes(axes), mesh, rules),
                opt_shapes, mesh)
            b_sh = _fit_shardings(_batch_shardings(batch_specs, mesh, rules),
                                  batch_specs, mesh)
            jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                             out_shardings=(p_sh, o_sh, None))
            lowered = jitted.lower(param_shapes, opt_shapes, batch_specs)
        elif shape.kind == "prefill":
            step = steps_lib.make_prefill_step(cfg, max_len=shape.seq_len)
            batch_specs = steps_lib.prefill_specs(cfg, shape)
            b_sh = _fit_shardings(_batch_shardings(batch_specs, mesh, rules),
                                  batch_specs, mesh)
            cache_shapes = jax.eval_shape(
                lambda: M.init_cache(None, cfg, shape.global_batch,
                                     shape.seq_len,
                                     enc_len=shape.seq_len
                                     if cfg.encoder_layers else None))
            c_sh = _fit_shardings(
                logical.param_specs(_stacked_cache_axes(cfg), mesh, rules),
                cache_shapes, mesh)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh),
                             out_shardings=(None, c_sh))
            lowered = jitted.lower(param_shapes, batch_specs)
        else:  # decode
            step = steps_lib.make_decode_step(cfg)
            token, cache_specs, pos = steps_lib.decode_specs(cfg, shape)
            c_sh = _fit_shardings(
                logical.param_specs(_stacked_cache_axes(cfg), mesh, rules),
                cache_specs, mesh)
            t_spec = P(tuple(a for a in ("pod", "data")
                             if a in mesh.axis_names))
            t_sh = _fit_shardings(NamedSharding(mesh, t_spec), token, mesh)
            jitted = jax.jit(step, in_shardings=(p_sh, t_sh, c_sh, None),
                             out_shardings=(None, c_sh))
            lowered = jitted.lower(param_shapes, token, cache_specs, pos)
        return lowered.compile()


def _metric_cfg(cfg, shape, reps: int):
    """Unrolled small-depth variant for cost measurement.

    XLA's cost analysis counts while-loop bodies once, so metric compiles
    unroll the layer stack (and inner chunk scans / the chunked loss) and
    the full-depth costs are fitted linearly from 1-rep and 2-rep runs."""
    import dataclasses as dc
    kw = dict(n_layers=reps * len(cfg.pattern), scan_layers=False,
              loss_chunk=shape.seq_len, unroll_inner=True)
    # mLSTM/sLSTM inner scans are NOT unrolled (32k/64 = 512 body copies
    # explode compile time); their in-scan flops are added analytically.
    return dc.replace(cfg, **kw)


def _metrics_of(compiled):
    ca = compiled.cost_analysis() or {}
    by, cnt = rl.parse_collectives(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": sum(by.values()), "by_kind": by, "cnt": cnt}


def _fit(m1, m2, reps):
    """metric(reps) = outside + body*reps, from 1-rep/2-rep measurements."""
    body = {k: max(m2[k] - m1[k], 0.0) for k in ("flops", "bytes", "coll")}
    out = {k: max(m1[k] - body[k], 0.0) for k in body}
    fitted = {k: out[k] + body[k] * reps for k in body}
    kinds = set(m1["by_kind"]) | set(m2["by_kind"])
    fitted["by_kind"] = {}
    for k in kinds:
        a, b2 = m1["by_kind"].get(k, 0.0), m2["by_kind"].get(k, 0.0)
        body_k = max(b2 - a, 0.0)
        fitted["by_kind"][k] = max(a - body_k, 0.0) + body_k * reps
    fitted["cnt"] = {k: m2["cnt"].get(k, 0) for k in kinds}
    return fitted


def _xlstm_extra_flops(cfg, shape):
    """Analytic add-back for the xLSTM inner scans (not unrollable at
    metric-compile time): sLSTM recurrent R-matmuls and the mLSTM chunk
    body (intra-chunk qk/value products + state update/inter-chunk reads).
    Projections live outside the scans and are fitted empirically."""
    if cfg.xlstm is None or shape.kind == "decode":
        return 0.0
    nh = cfg.xlstm.n_heads
    tokens = shape.global_batch * shape.seq_len
    mult = 3.0 if shape.kind == "train" else 1.0
    total = 0.0
    n_slstm = sum(1 for k in cfg.pattern if k == "slstm") * cfg.reps
    if n_slstm:
        hd = cfg.d_model // nh
        total += 2.0 * nh * hd * 4 * hd * tokens * n_slstm * mult
    n_mlstm = sum(1 for k in cfg.pattern if k == "mlstm") * cfg.reps
    if n_mlstm:
        di = cfg.xlstm.d_inner(cfg.d_model)
        hd = di // nh
        L = cfg.xlstm.chunk
        # per token: qk + y_num ~ 4*L*hd*nh ; state update + inter ~ 6*hd^2*nh
        per_tok = 4.0 * L * hd * nh + 6.0 * hd * hd * nh
        total += per_tok * tokens * n_mlstm * mult
    return total


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             rules=None, opt_overrides=None, verbose=True,
             cfg_overrides=None, metrics: bool = True,
             microbatch: int | None = None):
    """Dry-run one (arch x shape x mesh) cell.

    1. full-depth scan compile  -> proof-of-compile + memory_analysis
    2. unrolled 1-rep + 2-rep metric compiles -> fitted FLOPs/bytes/coll
       (``metrics=False`` skips #2 — multi-pod sweep: compile proof +
       memory + collective schedule only; roofline terms come from the
       single-pod table)
    """
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    chips = mesh.devices.size
    shape = SHAPES[shape_name]
    rules = rules or _rules_for(shape, mesh)
    cfg = configs.lm_config(arch, **(cfg_overrides or {}))
    ok, why = applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "skipped": why}

    t0 = time.monotonic()
    param_shapes, _ = steps_lib.eval_shape_init(cfg)
    n_active = rl.active_params(cfg, param_shapes)
    n_total = rl.count_params(param_shapes)
    model_flops = rl.model_flops_for(cfg, shape.kind, shape.seq_len,
                                     shape.global_batch, n_active)

    full = _compile_cell(cfg, shape, mesh, rules, opt_overrides,
                         microbatch=microbatch if microbatch is not None
                         else MICROBATCH.get(arch, 1))
    t_full = time.monotonic() - t0
    if metrics:
        m1 = _metrics_of(_compile_cell(_metric_cfg(cfg, shape, 1), shape,
                                       mesh, rules, opt_overrides))
        m2 = _metrics_of(_compile_cell(_metric_cfg(cfg, shape, 2), shape,
                                       mesh, rules, opt_overrides))
        fitted = _fit(m1, m2, cfg.reps)
        fitted["flops"] += _xlstm_extra_flops(cfg, shape) / chips
    else:
        fitted = _metrics_of(full)  # raw: while bodies counted once

    ma = full.memory_analysis()
    mem = {"argument_mb": ma.argument_size_in_bytes / 2**20,
           "output_mb": ma.output_size_in_bytes / 2**20,
           "temp_mb": ma.temp_size_in_bytes / 2**20,
           "peak_mb": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                       + ma.temp_size_in_bytes) / 2**20}
    row = rl.Roofline(arch=arch, shape=shape_name, mesh=mesh_name,
                      chips=chips, hlo_flops=fitted["flops"],
                      hlo_bytes=fitted["bytes"], coll_bytes=fitted["coll"],
                      coll_by_kind=fitted["by_kind"],
                      coll_count=fitted["cnt"], model_flops=model_flops,
                      mem_per_device=mem)
    d = row.to_dict()
    d.update({"compile_s": time.monotonic() - t0, "compile_full_s": t_full,
              "n_params": n_total, "n_active": n_active,
              "metrics_mode": "fitted" if metrics else "raw"})
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} on {mesh_name}: "
              f"peak {mem['peak_mb']/1024:.2f} GB/device | "
              f"flops/chip {d['hlo_flops_per_chip']:.3e} | "
              f"coll {d['coll_bytes_per_chip']/2**20:.1f} MB | "
              f"bound={d['bottleneck']} useful={d['usefulness']*100:.0f}% "
              f"| compile {d['compile_s']:.0f}s", flush=True)
    return d


def _stacked_cache_axes(cfg):
    return M.cache_axes(cfg)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-metrics", action="store_true")
    ap.add_argument("--impl", default=None, choices=["xla", "pallas"],
                    help="point-op execute backend for the PNN cells")
    ap.add_argument("--train", action="store_true",
                    help="lower the PNN fine-tune step (value_and_grad + "
                         "AdamW) instead of the serving step — proves the "
                         "backward pass compiles at production scale")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    from repro.launch.pnn_cell import PNN_SHAPES, PNN_VARIANTS, run_pnn_cell

    cells = []
    if args.all:
        for arch in configs.ARCHS:
            for shape in SHAPES:
                cells.append((arch, shape))
        for variant in PNN_VARIANTS:
            cells.append((variant, "pnn_289k"))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    rows, failures = [], []

    def flush():
        if args.out:
            with open(args.out, "w") as f:
                json.dump({"rows": rows, "failures": failures}, f, indent=1)

    for mp in meshes:
        for arch, shape in cells:
            try:
                if arch in PNN_VARIANTS:
                    rows.append(run_pnn_cell(
                        arch, shape, multi_pod=mp, impl=args.impl,
                        kind="train" if args.train else "serve"))
                else:
                    rows.append(run_cell(arch, shape, multi_pod=mp,
                                         metrics=not args.no_metrics))
            except Exception as e:  # noqa: BLE001 - report and continue
                traceback.print_exc()
                failures.append({"arch": arch, "shape": shape,
                                 "multi_pod": mp, "error": str(e)})
            flush()  # incremental: a timeout never loses completed cells
    real = [r for r in rows if "skipped" not in r]
    print(rl.format_table(real))
    for r in rows:
        if "skipped" in r:
            print(f"[skip] {r['arch']} x {r['shape']}: {r['skipped']}")
    if failures:
        print(f"FAILURES: {len(failures)}")
        for f_ in failures:
            print("  ", f_)
        sys.exit(1)


if __name__ == "__main__":
    main()

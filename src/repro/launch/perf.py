import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: re-run a dry-run cell under named variants and
report the roofline-term deltas (hypothesis -> change -> before/after).

Variants are *structural changes* (sharding rules or config knobs), so a
variant row is directly comparable with the baseline row of the same cell.

Usage:
  python -m repro.launch.perf --cell granite-moe-3b-a800m:train_4k \
      --variants baseline,a2a_moe --out results/perf_granite.json
"""
import argparse
import dataclasses
import json

from repro import configs
from repro.dist import logical


def _moe_override(arch, **kw):
    cfg = configs.lm_config(arch)
    return {"moe": dataclasses.replace(cfg.moe, **kw)}


VARIANTS = {
    # paper/v0 baseline
    "baseline": lambda arch: {},
    # H1: MoE token movement via grouped all-to-all instead of global-sort
    # gathers (dominant collective term on MoE cells).
    "a2a_moe": lambda arch: {
        "cfg_overrides": _moe_override(arch, dispatch="grouped_a2a")},
    # H2: small models should not be tensor-parallel: give the model axis
    # to data parallelism (per-layer collectives vanish; pure DP grads).
    "dp_only": lambda arch: {
        "rules": logical.rules_with(
            batch=("pod", "data", "model"), ff=None, vocab=None,
            seq_shard=None, embed_fsdp=("data", "model"),
            expert_cap=None, heads=None, ssm_heads=None)},
    # H4: larger flash chunk (fewer scan steps, bigger tiles).
    "flash4k": lambda arch: {"cfg_overrides": {"flash_chunk": 4096}},
    # H5: no remat (memory-for-flops trade: removes the recompute pass).
    "no_remat": lambda arch: {"cfg_overrides": {"remat": False}},
    # H2b: dp_only + no remat (memory is plentiful without TP, so stop
    # paying the recompute flops/bytes).
    "dp_no_remat": lambda arch: {
        "rules": VARIANTS["dp_only"](arch)["rules"],
        "cfg_overrides": {"remat": False}},
    # H1b: grouped A2A + microbatch 2 (halves the per-step FSDP param
    # re-gathers that dominate what's left of t_coll).
    "a2a_mb2": lambda arch: {
        "cfg_overrides": _moe_override(arch, dispatch="grouped_a2a"),
        "microbatch": 2},
    # H1c: grouped A2A + microbatch 8.
    "a2a_mb8": lambda arch: {
        "cfg_overrides": _moe_override(arch, dispatch="grouped_a2a"),
        "microbatch": 8},
    # H1d: grouped A2A + bf16 parameters (f32 optimizer states remain):
    # halves FSDP param all-gather wire bytes — the residual t_coll term.
    "a2a_bf16": lambda arch: {
        "cfg_overrides": {**_moe_override(arch, dispatch="grouped_a2a"),
                          "param_dtype": "bfloat16"}},
}


PNN_VARIANTS_PERF = {
    # v0 baseline: clouds -> data, leaves -> model, leaf-chunked ops
    "baseline": {},
    # H-P4: shard the flat per-point tensors over model so the
    # block->flat scatters stop all-reducing.
    "points_sharded": {"rules": logical.rules_with(points="model")},
    # H-P1: shard leaves over ALL chips (clouds replicated): the paper's
    # inter-block parallelism at full pod width.
    "blocks_all": {"rules": logical.rules_with(
        batch=None, blocks=("data", "model"))},
    # H-P2: bigger leaf chunks (fewer scan steps <-> larger live tiles).
    "chunk2k": {"leaf_chunk": 2048},
    # H-P3: paper-baseline global ops (PointAcc-style) for the BPPO
    # speedup comparison at pod scale.
    "global_ops": {"point_ops": "global", "batch": 16},
}


def run_variant(arch, shape, variant, multi_pod=False):
    from repro.launch.dryrun import run_cell
    from repro.launch.pnn_cell import PNN_VARIANTS, run_pnn_cell
    if arch in PNN_VARIANTS:
        spec = dict(PNN_VARIANTS_PERF[variant])
        row = run_pnn_cell(arch, shape, multi_pod=multi_pod, **spec)
    else:
        spec = VARIANTS[variant](arch)
        row = run_cell(arch, shape, multi_pod=multi_pod,
                       rules=spec.get("rules"),
                       cfg_overrides=spec.get("cfg_overrides"),
                       microbatch=spec.get("microbatch"))
    row["variant"] = variant
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    arch, shape = args.cell.split(":")
    rows = []
    for v in args.variants.split(","):
        try:
            rows.append(run_variant(arch, shape, v, args.multi_pod))
        except Exception as e:  # noqa: BLE001
            import traceback
            traceback.print_exc()
            rows.append({"arch": arch, "shape": shape, "variant": v,
                         "error": str(e)})
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
    for r in rows:
        if "error" in r:
            print(f"{r['variant']}: ERROR {r['error'][:120]}")
            continue
        print(f"{r['variant']:12s} t_comp={r['t_compute_s']*1e3:9.2f}ms "
              f"t_mem={r['t_memory_s']*1e3:9.2f}ms "
              f"t_coll={r['t_collective_s']*1e3:9.2f}ms "
              f"bound={r['bottleneck']:10s} useful={r['usefulness']*100:5.1f}% "
              f"peak={r['mem_per_device']['peak_mb']/1024:6.2f}GB")


if __name__ == "__main__":
    main()

"""End-to-end driver: train a PointNet++ classifier on synthetic shapes with
FractalCloud block-parallel point ops, with checkpoint/restart + straggler
monitoring (the full training substrate).

Run:  PYTHONPATH=src python examples/train_pointnet.py \
          [--steps 300] [--point-ops bppo|global] [--ckpt /tmp/pnn_ckpt]

Compare final accuracy across --point-ops to reproduce the paper's
accuracy-preservation claim (Fig. 14) at laptop scale.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import synthetic
from repro.models import pnn
from repro.train import checkpoint as ckpt_lib
from repro.train import optimizer as opt_lib
from repro.train.monitor import StepMonitor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--n-points", type=int, default=512)
    ap.add_argument("--point-ops", default="bppo",
                    choices=["bppo", "global"])
    ap.add_argument("--th", type=int, default=64)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = pnn.pointnet2_cls(n=args.n_points, point_ops=args.point_ops,
                            th=args.th)
    params = pnn.init(jax.random.PRNGKey(args.seed), cfg)
    opt_cfg = opt_lib.OptConfig(lr=2e-3, warmup=20,
                                total_steps=args.steps, weight_decay=1e-4)
    opt_state = opt_lib.init(params)
    start = 0
    saver = ckpt_lib.AsyncCheckpointer(args.ckpt) if args.ckpt else None
    if saver and (last := ckpt_lib.latest_step(args.ckpt)) is not None:
        state, manifest = ckpt_lib.restore(
            args.ckpt, last, {"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        start = manifest["extra"]["next_step"]
        print(f"resumed from step {last}")

    @jax.jit
    def train_step(params, opt_state, pts, labels):
        def loss_f(p):
            logits = jax.vmap(lambda c: pnn.apply(p, cfg, c))(pts)
            ll = jax.nn.log_softmax(logits)
            return -jnp.mean(jnp.take_along_axis(ll, labels[:, None], 1))

        loss, grads = jax.value_and_grad(loss_f)(params)
        params, opt_state, om = opt_lib.update(opt_cfg, grads, opt_state,
                                               params)
        return params, opt_state, loss

    @jax.jit
    def eval_acc(params, pts, labels):
        logits = jax.vmap(lambda c: pnn.apply(params, cfg, c))(pts)
        return jnp.mean(jnp.argmax(logits, -1) == labels)

    monitor = StepMonitor()
    for step in range(start, args.steps):
        pts, labels = synthetic.classification_batch(
            args.seed, step, args.batch, args.n_points)
        t0 = time.time()
        params, opt_state, loss = train_step(params, opt_state, pts, labels)
        loss.block_until_ready()
        straggler = monitor.record(step, time.time() - t0)
        if step % 25 == 0:
            accs = [float(eval_acc(params, *synthetic.classification_batch(
                args.seed + 999, s, args.batch, args.n_points)))
                for s in range(4)]
            print(f"step {step:4d} loss {float(loss):.4f} "
                  f"eval_acc {np.mean(accs):.3f}"
                  f"{' [straggler]' if straggler else ''}")
        if saver and step and step % 100 == 0:
            saver.save(step, {"params": params, "opt": opt_state},
                       extra={"next_step": step + 1})

    accs = [float(eval_acc(params, *synthetic.classification_batch(
        args.seed + 999, s, args.batch, args.n_points))) for s in range(8)]
    print(f"FINAL [{args.point_ops}] accuracy: {np.mean(accs):.3f} "
          f"({monitor.summary()})")
    if saver:
        saver.save(args.steps, {"params": params, "opt": opt_state},
                   extra={"next_step": args.steps})
        saver.wait()


if __name__ == "__main__":
    main()

"""LM-substrate smoke driver: pretrain a reduced assigned-arch config on a
synthetic token stream with the full fault-tolerant loop (checkpoint/
restart, straggler monitor, optional gradient compression).

Run:  PYTHONPATH=src python examples/lm_pretrain_smoke.py \
          [--arch smollm-135m] [--steps 60] [--compression int8]
"""
import argparse

import jax
import jax.numpy as jnp

from repro import configs
from repro.lm import model as M
from repro.lm import steps as steps_lib
from repro.train import loop as loop_lib
from repro.train import optimizer as opt_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m",
                    choices=sorted(configs.ARCHS))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--compression", default="none",
                    choices=["none", "bf16", "int8"])
    args = ap.parse_args()

    cfg = configs.lm_reduced(args.arch)
    opt_cfg = opt_lib.OptConfig(lr=1e-3, warmup=10,
                                total_steps=args.steps)

    def init_params():
        return M.init(jax.random.PRNGKey(0), cfg)[0]

    def next_batch(step):
        # synthetic structured stream: next-token = (token*7 + pos) % vocab
        key = jax.random.fold_in(jax.random.PRNGKey(42), step)
        toks = jax.random.randint(key, (args.batch, args.seq), 0, cfg.vocab)
        labels = (toks * 7 + jnp.arange(args.seq)[None, :]) % cfg.vocab
        batch = {"labels": labels}
        if cfg.encoder_layers:
            batch["frames"] = jax.random.normal(
                key, (args.batch, args.seq, cfg.d_model)) * 0.1
            batch["dec_tokens"] = toks
        elif cfg.frontend == "embeddings":
            batch["frames"] = jax.random.normal(
                key, (args.batch, args.seq, cfg.d_model)) * 0.1
        else:
            batch["tokens"] = toks
        return batch

    base_step = steps_lib.make_train_step(cfg, opt_cfg)
    jit_step = jax.jit(base_step)

    def train_step(params, opt_state, batch, return_grads=False):
        if return_grads:
            def loss_f(p):
                return steps_lib.loss_fn(p, cfg, batch)[0]
            loss, grads = jax.value_and_grad(loss_f)(params)
            return grads, {"loss": loss}
        return jit_step(params, opt_state, batch)

    loop_cfg = loop_lib.LoopConfig(
        total_steps=args.steps, ckpt_dir=args.ckpt, ckpt_every=20,
        log_every=10, grad_compression=args.compression)
    params, _, info = loop_lib.run(
        loop_cfg, init_params=init_params, train_step=train_step,
        next_batch=next_batch, opt_cfg=opt_cfg)
    h = info["history"]
    print(f"[{args.arch}] loss {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f} "
          f"over {len(h)} steps; monitor={info['monitor']}")


if __name__ == "__main__":
    main()

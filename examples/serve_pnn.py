"""Batched point-cloud segmentation serving — the paper's deployment mode.

A request queue of LiDAR-scale clouds flows through the Fractal pipeline
(partition -> BPPO -> PNN) in fixed-size batches; reports per-cloud latency
and sustained throughput.

Run:  PYTHONPATH=src python examples/serve_pnn.py [--n 8192] [--requests 32]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import synthetic
from repro.models import pnn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--th", type=int, default=256)
    ap.add_argument("--point-ops", default="bppo",
                    choices=["bppo", "global"])
    ap.add_argument("--impl", default=None, choices=["xla", "pallas"],
                    help="bppo execute backend (default: $REPRO_POINT_IMPL"
                         " or xla)")
    args = ap.parse_args()

    cfg = pnn.pointnext_seg(n=args.n, point_ops=args.point_ops, th=args.th,
                            impl=args.impl)
    params = pnn.init(jax.random.PRNGKey(0), cfg)

    @jax.jit
    def serve(params, clouds):
        return jax.vmap(lambda c: pnn.apply(params, cfg, c))(clouds)

    # Warmup (compile)
    clouds, _ = synthetic.segmentation_batch(0, 0, args.batch, args.n)
    t0 = time.time()
    serve(params, clouds).block_until_ready()
    print(f"compiled in {time.time() - t0:.1f}s "
          f"({args.point_ops} point ops, impl={args.impl or 'default'}, "
          f"n={args.n}, th={args.th})")

    done, lat = 0, []
    t_start = time.time()
    for r in range(args.requests // args.batch):
        clouds, _ = synthetic.segmentation_batch(0, r + 1, args.batch,
                                                 args.n)
        t0 = time.time()
        out = serve(params, clouds)
        out.block_until_ready()
        lat.append(time.time() - t0)
        done += args.batch
        # sanity: segmentation logits per point
        assert out.shape == (args.batch, args.n, cfg.num_classes)
    wall = time.time() - t_start
    print(f"served {done} clouds x {args.n} pts: "
          f"p50 latency {np.percentile(lat, 50) * 1e3:.1f} ms/batch, "
          f"throughput {done / wall:.2f} clouds/s "
          f"({done * args.n / wall / 1e6:.2f} Mpts/s)")


if __name__ == "__main__":
    main()

"""Batched point-cloud segmentation serving — thin client of ``repro.serve``.

A mixed-size request stream flows through the serving subsystem
(docs/DESIGN.md §9): each cloud is padded to its minimal shape bucket, a
per-bucket queue packs fixed microbatches under a max-wait deadline, and a
plan cache keeps exactly one fractal-partition plan per (bucket, th,
strategy) and one compiled forward per (bucket, impl).  Compile happens in
``warm()`` — *before* the stream — so reported latencies never include it.

Run:  PYTHONPATH=src python examples/serve_pnn.py \
          [--buckets 1024,4096] [--requests 16] [--impl pallas] [--mesh auto]
"""
import argparse

from repro import serve
from repro.data import synthetic


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--buckets", default="1024,4096",
                    help="comma-separated shape-bucket ladder")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--microbatch", type=int, default=2)
    ap.add_argument("--max-wait-ms", type=float, default=20.0)
    ap.add_argument("--th", type=int, default=256)
    ap.add_argument("--variant", default="pointnext",
                    choices=["pointnet2", "pointnext", "pointvector"])
    ap.add_argument("--point-ops", default="bppo",
                    choices=["bppo", "global"])
    ap.add_argument("--impl", default=None, choices=["xla", "pallas"],
                    help="bppo execute backend (default: $REPRO_POINT_IMPL"
                         " or xla)")
    ap.add_argument("--mesh", default="none", choices=["none", "auto"],
                    help="auto: shard microbatches over the elastic host "
                         "mesh (repro.dist)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    buckets = tuple(int(b) for b in args.buckets.split(","))
    cfg = serve.ServeConfig(
        buckets=buckets, microbatch=args.microbatch,
        max_wait_s=args.max_wait_ms / 1e3, variant=args.variant,
        th=args.th, point_ops=args.point_ops, impl=args.impl,
        mesh=args.mesh)
    engine = serve.ServeEngine(cfg, seed=args.seed)

    compile_s = engine.warm()
    print(f"warmed {len(compile_s)} buckets "
          f"({args.point_ops} point ops, impl={engine.impl}, th={args.th}, "
          f"mesh={args.mesh}): "
          + ", ".join(f"n={b} in {s:.1f}s" for b, s in compile_s.items())
          + "  [excluded from latencies]")

    sizes = serve.mixed_request_sizes(buckets, args.requests, args.seed)
    expect = {}
    for r, n in enumerate(sizes):
        clouds, _ = synthetic.segmentation_batch(args.seed, r, 1, n)
        rid = engine.submit(clouds[0])
        expect[rid] = n
        for done in engine.step():
            # pop-on-read; sanity: per-point logits for the real points
            assert engine.take(done).shape == (expect.pop(done),
                                               cfg.num_classes)
    for done in engine.flush():
        assert engine.take(done).shape == (expect.pop(done),
                                           cfg.num_classes)

    st = engine.stats()
    if st["clouds_per_s"] is None:   # no microbatch completed
        print(f"served {st['served']} clouds (no completed window)")
    else:
        print(f"served {st['served']} clouds in {st['wall_s']:.2f}s: "
              f"{st['clouds_per_s']:.2f} clouds/s "
              f"({st['mpts_per_s']:.3g} Mpts/s)")
    for b, row in sorted(st["buckets"].items()):
        print(f"  bucket n={b}: {row['count']} clouds, "
              f"p50 {row['p50_ms']:.1f} / p95 {row['p95_ms']:.1f} / "
              f"p99 {row['p99_ms']:.1f} ms")
    pc = st["plan_cache"]
    print(f"plan cache: {pc['executables']} executables, "
          f"{pc['hits']} hits, {pc['misses']} misses "
          f"(one trace per key: "
          f"{all(v == 1 for v in pc['traces'].values())})")


if __name__ == "__main__":
    main()

"""Quickstart: Fractal partitioning + block-parallel point ops in 60 lines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import core
from repro.core import ref

# A clustered scene: two objects + clutter (the distribution Fractal's
# shape-aware splits exploit).
rng = np.random.default_rng(0)
pts = jnp.asarray(np.concatenate([
    rng.normal([0, 0, 0], 0.3, (2000, 3)),
    rng.normal([3, 1, 0], 0.5, (1500, 3)),
    rng.uniform(-1, 4, (596, 3)),
]).astype(np.float32))
n = pts.shape[0]

# 1. Fractal: shape-aware, sorter-free partitioning (paper Alg. 1).
part = jax.jit(lambda p: core.partition(p, th=256))(pts)
print(f"partitioned {n} points -> {int(part.num_leaves)} blocks "
      f"(max {int(part.max_leaf_vsize)} pts <= th=256), "
      f"{int(part.traversals)} traversals, {int(part.sort_passes)} sorts")

# 2. Block-wise FPS: one fixed rate, fully parallel across blocks.
samp = jax.jit(lambda p: core.blockwise_fps(
    core.partition(p, th=256), rate=0.25, k_out=n // 4, bs=256))(pts)
print(f"sampled {int(samp.valid.sum())}/{n // 4} points block-wise")

# 3. Block-wise ball query: each center searches its parent window only.
nb = jax.jit(lambda p: core.blockwise_ball_query(
    core.partition(p, th=256),
    core.blockwise_fps(core.partition(p, th=256), rate=0.25,
                       k_out=n // 4, bs=256),
    radius=0.3, num=16, w=512))(pts)
print(f"grouping: mean {float(jnp.mean(nb.cnt[samp.valid])):.1f} "
      f"in-radius neighbors per center")

# 4. Compare against the global O(n^2) baseline (PointAcc-style).
sval = np.asarray(samp.valid)
centers = np.asarray(part.coords)[np.asarray(samp.idx)[sval]]
g_idx, g_cnt = ref.ball_query(part.coords, part.valid,
                              jnp.asarray(centers),
                              jnp.ones(len(centers), bool), 0.3, 16)
g_idx, g_cnt = np.asarray(g_idx), np.asarray(g_cnt)
b_idx, b_msk = np.asarray(nb.idx)[sval], np.asarray(nb.mask)[sval]
recalls = [len(set(g_idx[i][:min(g_cnt[i], 16)]) & set(b_idx[i][b_msk[i]]))
           / max(min(g_cnt[i], 16), 1) for i in range(len(centers))]
print(f"block-wise neighbor recall vs global search: "
      f"{np.mean(recalls) * 100:.1f}% (paper: accuracy recovered by "
      f"retraining; see benchmarks/accuracy.py)")

"""Room-scale scene segmentation — thin client of ``repro.scene``.

One 16k–1M-point synthetic scene flows through the streaming scene path
(docs/DESIGN.md §10): a coarse fractal pre-partition cuts it into
DFT-contiguous tiles, each tile plus its halo ring is admitted to a shape
bucket and served by the plan-cached engine (one compile per bucket, done
in ``warm()``), and per-point logits stitch back under the owner-tile
rule.  No O(n²) op is ever materialized; peak memory is one microbatch of
tile tensors plus the (n, classes) output.

Run:  PYTHONPATH=src python examples/segment_scene.py \
          [--n 65536] [--tile-points 4096] [--halo 0.15] [--impl pallas]
"""
import argparse
import resource
import time

import numpy as np

from repro import scene
from repro.data import synthetic


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=65536)
    ap.add_argument("--tile-points", type=int, default=4096)
    ap.add_argument("--halo", type=float, default=0.15,
                    help="halo radius (0 disables border context)")
    ap.add_argument("--th", type=int, default=256)
    ap.add_argument("--microbatch", type=int, default=4)
    ap.add_argument("--variant", default="pointnet2",
                    choices=["pointnet2", "pointnext", "pointvector"])
    ap.add_argument("--impl", default=None, choices=["xla", "pallas"],
                    help="bppo execute backend (default: $REPRO_POINT_IMPL"
                         " or xla)")
    ap.add_argument("--mesh", default="none", choices=["none", "auto"],
                    help="auto: shard tile microbatches over the elastic "
                         "host mesh (repro.dist)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    t0 = time.monotonic()
    coords, labels = synthetic.scene(args.seed, args.n)
    print(f"scene: {args.n} points, {len(np.unique(labels))} shape classes "
          f"({time.monotonic() - t0:.1f}s to generate)")

    cfg = scene.SceneConfig(
        tile_points=args.tile_points, halo=args.halo, th=args.th,
        microbatch=args.microbatch, variant=args.variant, impl=args.impl,
        mesh=args.mesh)
    eng = scene.SceneEngine(cfg, seed=args.seed)
    t0 = time.monotonic()
    compile_s = eng.warm()
    print(f"warmed {len(compile_s)} buckets (impl={eng.impl}, "
          f"th={args.th}, mesh={args.mesh}) in "
          f"{time.monotonic() - t0:.1f}s  [excluded from throughput]")

    t0 = time.monotonic()
    logits, plan = eng.infer(coords)
    dt = time.monotonic() - t0
    assert logits.shape == (args.n, cfg.num_classes)

    print(f"tiled: {plan.num_tiles} tiles (<= {args.tile_points} owned pts "
          f"each), {plan.halo_points} halo context points, "
          f"max tile cloud {plan.max_tile_n}")
    print(f"inferred: {args.n / dt:,.0f} points/s ({dt:.2f}s end to end, "
          f"tiling + dispatch + stitch)")
    pred = logits.argmax(-1)
    agree = (pred == labels).mean()
    counts = np.bincount(pred, minlength=cfg.num_classes)
    print(f"predictions (untrained params — structure demo, not accuracy): "
          f"class counts {counts.tolist()}, label agreement {agree:.3f}")
    rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024
    print(f"peak RSS {rss:.0f} MB "
          f"(~{1e6 * rss / args.n:.0f} bytes/point at this n)")


if __name__ == "__main__":
    main()
